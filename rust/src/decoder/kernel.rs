//! Decode kernel ladder: rung selection + the posterior-row prep kernel.
//!
//! The beam search has the same shape as the quantized GEMM and
//! elementwise paths: a reference implementation that defines the
//! semantics, and faster rungs that must reproduce it.  The ladder here
//! has one extra step at the bottom because the *data layout* changed,
//! not just the instruction mix:
//!
//! - `Reference` — the seed per-hypothesis `HashMap` prefix beam search
//!   ([`crate::decoder::search`] keeps it verbatim).  Defines the scores.
//! - `Scalar` — struct-of-arrays beam lanes, CSR trie walk, partial-select
//!   pruning; plain scalar arithmetic.
//! - `Avx2` / `Neon` — the SoA engine with the posterior-row prep
//!   (f32→f64 widening + phone-floor mask) vectorized.
//!
//! **Bit-exactness contract.**  All SoA rungs (`Scalar`/`Avx2`/`Neon`)
//! produce bit-identical hypotheses: the vector rungs only use exact
//! operations (f32→f64 convert, compare), never a polynomial.  The SoA
//! rungs match `Reference` to ≤1e-9 in final scores with an identical
//! 1-best word sequence — exact equality is impossible because the seed
//! search iterates a `HashMap`, so its log-sum-exp accumulation order is
//! arbitrary; the SoA engine accumulates in deterministic lane order.
//!
//! `QUANTASR_DECODE_KERNEL` forces a rung
//! (`reference|scalar|avx2|neon|auto`), mirroring `QUANTASR_KERNEL` /
//! `QUANTASR_EW_KERNEL`.  Unknown or unavailable values warn and fall
//! back to auto — tuning knobs never panic a serving process.

/// Which decode implementation to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeKernel {
    /// Seed per-hypothesis HashMap beam search — the semantic reference.
    Reference,
    /// Struct-of-arrays beam lanes, scalar arithmetic.
    Scalar,
    #[cfg(target_arch = "x86_64")]
    /// SoA lanes + AVX2 posterior-row prep.
    Avx2,
    #[cfg(target_arch = "aarch64")]
    /// SoA lanes + NEON posterior-row prep.
    Neon,
    /// Resolve at runtime: forced rung if set, else best available.
    Auto,
}

impl DecodeKernel {
    /// Concrete rung this resolves to at runtime.  Clamps a forced SIMD
    /// rung back to `Scalar` when the CPU lacks the feature — the
    /// soundness gate for the `#[target_feature]` dispatch below.
    pub fn resolve(self) -> DecodeKernel {
        let k = match self {
            DecodeKernel::Auto => forced_decode_kernel().unwrap_or_else(Self::best_available),
            other => other,
        };
        #[cfg(target_arch = "x86_64")]
        if k == DecodeKernel::Avx2 && !crate::quant::gemm::avx2_available() {
            return DecodeKernel::Scalar;
        }
        k
    }

    fn best_available() -> DecodeKernel {
        #[cfg(target_arch = "x86_64")]
        if crate::quant::gemm::avx2_available() {
            return DecodeKernel::Avx2;
        }
        #[cfg(target_arch = "aarch64")]
        return DecodeKernel::Neon;
        #[allow(unreachable_code)]
        DecodeKernel::Scalar
    }
}

/// `QUANTASR_DECODE_KERNEL` forcing, parsed once per process.
pub fn forced_decode_kernel() -> Option<DecodeKernel> {
    static ONCE: std::sync::OnceLock<Option<DecodeKernel>> = std::sync::OnceLock::new();
    *ONCE.get_or_init(|| {
        let v = std::env::var("QUANTASR_DECODE_KERNEL").ok()?;
        match v.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => None,
            "reference" => Some(DecodeKernel::Reference),
            "scalar" => Some(DecodeKernel::Scalar),
            #[cfg(target_arch = "x86_64")]
            "avx2" if crate::quant::gemm::avx2_available() => Some(DecodeKernel::Avx2),
            #[cfg(target_arch = "aarch64")]
            "neon" => Some(DecodeKernel::Neon),
            other => {
                eprintln!(
                    "QUANTASR_DECODE_KERNEL='{other}' unknown or unavailable \
                     on this CPU; using auto"
                );
                None
            }
        }
    })
}

/// Prep one posterior frame for the SoA search: widen the f32 log-prob
/// row to f64 (scores accumulate in f64, matching the reference) and
/// mark which phones clear the pruning floor.  `active[p]` is the
/// phone-floor mask the beam expansion consults instead of re-comparing
/// per hypothesis.
///
/// Every rung performs the identical exact operations (convert, compare),
/// so outputs are bit-identical across the ladder.
pub fn prep_row(
    kernel: DecodeKernel,
    row: &[f32],
    floor: f64,
    row64: &mut Vec<f64>,
    active: &mut Vec<bool>,
) {
    row64.clear();
    row64.resize(row.len(), 0.0);
    active.clear();
    active.resize(row.len(), false);
    match kernel.resolve() {
        #[cfg(target_arch = "x86_64")]
        DecodeKernel::Avx2 => unsafe { prep_row_avx2(row, floor, row64, active) },
        #[cfg(target_arch = "aarch64")]
        DecodeKernel::Neon => unsafe { prep_row_neon(row, floor, row64, active) },
        _ => prep_row_scalar(row, floor, row64, active),
    }
}

fn prep_row_scalar(row: &[f32], floor: f64, row64: &mut [f64], active: &mut [bool]) {
    for (i, &x) in row.iter().enumerate() {
        let v = x as f64;
        row64[i] = v;
        active[i] = v >= floor;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn prep_row_avx2(row: &[f32], floor: f64, row64: &mut [f64], active: &mut [bool]) {
    use std::arch::x86_64::*;
    let n = row.len();
    let vfloor = _mm256_set1_pd(floor);
    let mut i = 0;
    while i + 4 <= n {
        // 4 f32 → 4 f64 (exact widening), then >= floor per lane.
        let x = _mm_loadu_ps(row.as_ptr().add(i));
        let wide = _mm256_cvtps_pd(x);
        _mm256_storeu_pd(row64.as_mut_ptr().add(i), wide);
        let ge = _mm256_cmp_pd::<_CMP_GE_OQ>(wide, vfloor);
        let mask = _mm256_movemask_pd(ge);
        for lane in 0..4 {
            active[i + lane] = mask & (1 << lane) != 0;
        }
        i += 4;
    }
    while i < n {
        let v = row[i] as f64;
        row64[i] = v;
        active[i] = v >= floor;
        i += 1;
    }
}

#[cfg(target_arch = "aarch64")]
unsafe fn prep_row_neon(row: &[f32], floor: f64, row64: &mut [f64], active: &mut [bool]) {
    use std::arch::aarch64::*;
    let n = row.len();
    let vfloor = vdupq_n_f64(floor);
    let mut i = 0;
    while i + 2 <= n {
        // 2 f32 → 2 f64 (exact widening), then >= floor per lane.
        let x = vld1_f32(row.as_ptr().add(i));
        let wide = vcvt_f64_f32(x);
        vst1q_f64(row64.as_mut_ptr().add(i), wide);
        let ge = vcgeq_f64(wide, vfloor);
        active[i] = vgetq_lane_u64::<0>(ge) != 0;
        active[i + 1] = vgetq_lane_u64::<1>(ge) != 0;
        i += 2;
    }
    while i < n {
        let v = row[i] as f64;
        row64[i] = v;
        active[i] = v >= floor;
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    fn rungs() -> Vec<DecodeKernel> {
        let mut r = vec![DecodeKernel::Scalar];
        #[cfg(target_arch = "x86_64")]
        if crate::quant::gemm::avx2_available() {
            r.push(DecodeKernel::Avx2);
        }
        #[cfg(target_arch = "aarch64")]
        r.push(DecodeKernel::Neon);
        r
    }

    #[test]
    fn prep_row_rungs_are_bit_identical() {
        forall("prep_row ladder", 200, 0xDEC0DE, |g: &mut Gen| {
            let n = g.usize_in(1, 67); // odd sizes exercise the tails
            let floor = g.f64_in(-14.0, -2.0);
            let row = g.vec_normal(n, 4.0);
            let mut base64 = Vec::new();
            let mut base_active = Vec::new();
            prep_row(DecodeKernel::Scalar, &row, floor, &mut base64, &mut base_active);
            for k in rungs() {
                let mut r64 = Vec::new();
                let mut act = Vec::new();
                prep_row(k, &row, floor, &mut r64, &mut act);
                for i in 0..n {
                    assert_eq!(r64[i].to_bits(), base64[i].to_bits(), "{k:?} lane {i}");
                }
                assert_eq!(act, base_active, "{k:?} mask");
            }
        });
    }

    #[test]
    fn prep_row_mask_matches_floor() {
        let row = [-1.0f32, -12.0, -11.9999, -30.0, 0.0];
        let mut r64 = Vec::new();
        let mut act = Vec::new();
        prep_row(DecodeKernel::Scalar, &row, -12.0, &mut r64, &mut act);
        assert_eq!(act, vec![true, true, true, false, true]);
        assert_eq!(r64[3], -30.0);
    }

    #[test]
    fn resolve_never_yields_auto() {
        assert_ne!(DecodeKernel::Auto.resolve(), DecodeKernel::Auto);
        assert_eq!(DecodeKernel::Scalar.resolve(), DecodeKernel::Scalar);
        assert_eq!(DecodeKernel::Reference.resolve(), DecodeKernel::Reference);
    }
}
