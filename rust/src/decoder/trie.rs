//! Lexicon prefix trie over phone ids — the simulator-scale stand-in for
//! the paper's lexicon transducer (§4).

use crate::sim::World;

/// Flat-array trie.  Node 0 is the root.
pub struct LexTrie {
    /// children[node] : sorted (phone, child) pairs.
    children: Vec<Vec<(u32, u32)>>,
    /// word ids terminating at each node.
    terminal: Vec<Vec<u32>>,
    num_words: usize,
}

impl LexTrie {
    pub fn from_world(world: &World) -> Self {
        let mut t = LexTrie {
            children: vec![Vec::new()],
            terminal: vec![Vec::new()],
            num_words: world.lexicon.len(),
        };
        for (wid, phones) in world.lexicon.iter().enumerate() {
            let mut node = 0u32;
            for &p in phones {
                node = t.child_or_insert(node, p);
            }
            t.terminal[node as usize].push(wid as u32);
        }
        t
    }

    fn child_or_insert(&mut self, node: u32, phone: u32) -> u32 {
        if let Some(c) = self.child(node, phone) {
            return c;
        }
        let new = self.children.len() as u32;
        self.children.push(Vec::new());
        self.terminal.push(Vec::new());
        let row = &mut self.children[node as usize];
        let pos = row.partition_point(|&(p, _)| p < phone);
        row.insert(pos, (phone, new));
        new
    }

    /// Child reached by `phone` from `node`, if any.
    #[inline]
    pub fn child(&self, node: u32, phone: u32) -> Option<u32> {
        let row = &self.children[node as usize];
        row.binary_search_by_key(&phone, |&(p, _)| p).ok().map(|i| row[i].1)
    }

    /// Words ending exactly at `node`.
    #[inline]
    pub fn words_at(&self, node: u32) -> &[u32] {
        &self.terminal[node as usize]
    }

    /// Phones leaving `node` (for beam expansion).
    #[inline]
    pub fn exits(&self, node: u32) -> &[(u32, u32)] {
        &self.children[node as usize]
    }

    pub fn num_nodes(&self) -> usize {
        self.children.len()
    }

    pub fn num_words(&self) -> usize {
        self.num_words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_lexicon_word_is_reachable() {
        let w = World::new();
        let t = LexTrie::from_world(&w);
        for (wid, phones) in w.lexicon.iter().enumerate() {
            let mut node = 0u32;
            for &p in phones {
                node = t.child(node, p).expect("path must exist");
            }
            assert!(
                t.words_at(node).contains(&(wid as u32)),
                "word {wid} missing at terminal node"
            );
        }
    }

    #[test]
    fn no_false_terminals_at_root() {
        let w = World::new();
        let t = LexTrie::from_world(&w);
        assert!(t.words_at(0).is_empty(), "root must terminate no word");
        assert!(t.num_nodes() > w.lexicon.len()); // at least one node per word end
    }

    #[test]
    fn invalid_phone_has_no_child() {
        let w = World::new();
        let t = LexTrie::from_world(&w);
        assert!(t.child(0, 0).is_none()); // blank never enters the lexicon
        assert!(t.child(0, 999).is_none());
    }

    #[test]
    fn exits_are_sorted_unique() {
        let w = World::new();
        let t = LexTrie::from_world(&w);
        for n in 0..t.num_nodes() as u32 {
            let ex = t.exits(n);
            for win in ex.windows(2) {
                assert!(win[0].0 < win[1].0);
            }
        }
    }
}
