//! Lexicon prefix trie over phone ids — the simulator-scale stand-in for
//! the paper's lexicon transducer (§4).

use crate::sim::World;

/// Flat-array trie.  Node 0 is the root.
pub struct LexTrie {
    /// children[node] : sorted (phone, child) pairs.
    children: Vec<Vec<(u32, u32)>>,
    /// word ids terminating at each node.
    terminal: Vec<Vec<u32>>,
    num_words: usize,
}

impl LexTrie {
    pub fn from_world(world: &World) -> Self {
        let mut t = LexTrie {
            children: vec![Vec::new()],
            terminal: vec![Vec::new()],
            num_words: world.lexicon.len(),
        };
        for (wid, phones) in world.lexicon.iter().enumerate() {
            let mut node = 0u32;
            for &p in phones {
                node = t.child_or_insert(node, p);
            }
            t.terminal[node as usize].push(wid as u32);
        }
        t
    }

    fn child_or_insert(&mut self, node: u32, phone: u32) -> u32 {
        if let Some(c) = self.child(node, phone) {
            return c;
        }
        let new = self.children.len() as u32;
        self.children.push(Vec::new());
        self.terminal.push(Vec::new());
        let row = &mut self.children[node as usize];
        let pos = row.partition_point(|&(p, _)| p < phone);
        row.insert(pos, (phone, new));
        new
    }

    /// Child reached by `phone` from `node`, if any.
    #[inline]
    pub fn child(&self, node: u32, phone: u32) -> Option<u32> {
        let row = &self.children[node as usize];
        row.binary_search_by_key(&phone, |&(p, _)| p).ok().map(|i| row[i].1)
    }

    /// Words ending exactly at `node`.
    #[inline]
    pub fn words_at(&self, node: u32) -> &[u32] {
        &self.terminal[node as usize]
    }

    /// Phones leaving `node` (for beam expansion).
    #[inline]
    pub fn exits(&self, node: u32) -> &[(u32, u32)] {
        &self.children[node as usize]
    }

    pub fn num_nodes(&self) -> usize {
        self.children.len()
    }

    pub fn num_words(&self) -> usize {
        self.num_words
    }

    /// Flatten into the CSR view the struct-of-arrays beam search walks.
    pub fn to_csr(&self) -> TrieCsr {
        let n = self.children.len();
        let mut csr = TrieCsr {
            exit_off: Vec::with_capacity(n + 1),
            exit_phone: Vec::new(),
            exit_child: Vec::new(),
            word_off: Vec::with_capacity(n + 1),
            word_id: Vec::new(),
        };
        csr.exit_off.push(0);
        csr.word_off.push(0);
        for node in 0..n {
            for &(p, c) in &self.children[node] {
                csr.exit_phone.push(p);
                csr.exit_child.push(c);
            }
            csr.exit_off.push(csr.exit_phone.len() as u32);
            csr.word_id.extend_from_slice(&self.terminal[node]);
            csr.word_off.push(csr.word_id.len() as u32);
        }
        csr
    }
}

/// CSR (flat offset-array) view of [`LexTrie`].
///
/// The per-node `Vec<Vec<...>>` layout of the build-time trie costs one
/// pointer chase per beam expansion; the CSR view packs all exits and all
/// terminal words into four contiguous arrays so the SoA beam search
/// streams them with plain index arithmetic.  Phones within a node keep
/// the trie's sorted order, so walk order — and therefore log-sum-exp
/// accumulation order — is identical to iterating `LexTrie::exits`.
#[derive(Clone, Debug, Default)]
pub struct TrieCsr {
    /// exits of `node` live at `exit_off[node]..exit_off[node+1]`.
    pub exit_off: Vec<u32>,
    pub exit_phone: Vec<u32>,
    pub exit_child: Vec<u32>,
    /// words terminating at `node` live at `word_off[node]..word_off[node+1]`.
    pub word_off: Vec<u32>,
    pub word_id: Vec<u32>,
}

impl TrieCsr {
    /// (phone, child) exit pairs of `node`, in sorted phone order.
    #[inline]
    pub fn exits(&self, node: u32) -> impl Iterator<Item = (u32, u32)> + '_ {
        let lo = self.exit_off[node as usize] as usize;
        let hi = self.exit_off[node as usize + 1] as usize;
        (lo..hi).map(move |i| (self.exit_phone[i], self.exit_child[i]))
    }

    /// Words ending exactly at `node`.
    #[inline]
    pub fn words_at(&self, node: u32) -> &[u32] {
        let lo = self.word_off[node as usize] as usize;
        let hi = self.word_off[node as usize + 1] as usize;
        &self.word_id[lo..hi]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_lexicon_word_is_reachable() {
        let w = World::new();
        let t = LexTrie::from_world(&w);
        for (wid, phones) in w.lexicon.iter().enumerate() {
            let mut node = 0u32;
            for &p in phones {
                node = t.child(node, p).expect("path must exist");
            }
            assert!(
                t.words_at(node).contains(&(wid as u32)),
                "word {wid} missing at terminal node"
            );
        }
    }

    #[test]
    fn no_false_terminals_at_root() {
        let w = World::new();
        let t = LexTrie::from_world(&w);
        assert!(t.words_at(0).is_empty(), "root must terminate no word");
        assert!(t.num_nodes() > w.lexicon.len()); // at least one node per word end
    }

    #[test]
    fn invalid_phone_has_no_child() {
        let w = World::new();
        let t = LexTrie::from_world(&w);
        assert!(t.child(0, 0).is_none()); // blank never enters the lexicon
        assert!(t.child(0, 999).is_none());
    }

    #[test]
    fn csr_mirrors_trie_exactly() {
        let w = World::new();
        let t = LexTrie::from_world(&w);
        let csr = t.to_csr();
        assert_eq!(csr.exit_off.len(), t.num_nodes() + 1);
        assert_eq!(csr.word_off.len(), t.num_nodes() + 1);
        for n in 0..t.num_nodes() as u32 {
            let flat: Vec<(u32, u32)> = csr.exits(n).collect();
            assert_eq!(flat.as_slice(), t.exits(n), "exit mismatch at node {n}");
            assert_eq!(csr.words_at(n), t.words_at(n), "word mismatch at node {n}");
        }
    }

    #[test]
    fn exits_are_sorted_unique() {
        let w = World::new();
        let t = LexTrie::from_world(&w);
        for n in 0..t.num_nodes() as u32 {
            let ex = t.exits(n);
            for win in ex.windows(2) {
                assert!(win[0].0 < win[1].0);
            }
        }
    }
}
