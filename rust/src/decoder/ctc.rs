//! Phone-level CTC decoding: greedy best-path and prefix beam search
//! (Hannun et al. 2014 style, log domain, no LM).

use std::collections::HashMap;

pub const BLANK: u32 = 0;
const NEG_INF: f64 = -1e30;

#[inline]
fn logsumexp2(a: f64, b: f64) -> f64 {
    // ln_1p keeps precision when the smaller term is ~e^-40 of the larger
    // (1.0 + tiny would round the contribution away entirely).
    if a < b {
        b + (a - b).exp().ln_1p()
    } else if a == NEG_INF {
        NEG_INF
    } else {
        a + (b - a).exp().ln_1p()
    }
}

/// Greedy best-path + collapse. `log_probs` is `[t, num_labels]` row-major.
pub fn greedy(log_probs: &[f32], num_labels: usize) -> Vec<u32> {
    let t = log_probs.len() / num_labels;
    let mut out = Vec::new();
    let mut prev = BLANK;
    for i in 0..t {
        let row = &log_probs[i * num_labels..(i + 1) * num_labels];
        let best = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u32;
        if best != BLANK && best != prev {
            out.push(best);
        }
        prev = best;
    }
    out
}

/// CTC prefix beam search over phones (no lexicon/LM).  Returns the best
/// collapsed label sequence.
pub fn prefix_beam(log_probs: &[f32], num_labels: usize, beam: usize) -> Vec<u32> {
    let t = log_probs.len() / num_labels;
    // prefix → (lp ending in blank, lp ending in non-blank)
    let mut beams: HashMap<Vec<u32>, (f64, f64)> = HashMap::new();
    beams.insert(Vec::new(), (0.0, NEG_INF));
    for i in 0..t {
        let row = &log_probs[i * num_labels..(i + 1) * num_labels];
        let mut next: HashMap<Vec<u32>, (f64, f64)> = HashMap::new();
        for (prefix, &(lb, lnb)) in &beams {
            let total = logsumexp2(lb, lnb);
            // 1) blank: prefix unchanged
            {
                let e = next.entry(prefix.clone()).or_insert((NEG_INF, NEG_INF));
                e.0 = logsumexp2(e.0, total + row[BLANK as usize] as f64);
            }
            // 2) repeat last symbol: stays in the same prefix (non-blank)
            if let Some(&last) = prefix.last() {
                let e = next.entry(prefix.clone()).or_insert((NEG_INF, NEG_INF));
                e.1 = logsumexp2(e.1, lnb + row[last as usize] as f64);
            }
            // 3) extend with symbol s
            for s in 1..num_labels as u32 {
                let p_s = row[s as usize] as f64;
                if p_s < -14.0 {
                    continue; // inaudible — prune early
                }
                let base = if Some(&s) == prefix.last() {
                    lb // same symbol: only via the blank path
                } else {
                    total
                };
                if base <= NEG_INF {
                    continue;
                }
                let mut ext = prefix.clone();
                ext.push(s);
                let e = next.entry(ext).or_insert((NEG_INF, NEG_INF));
                e.1 = logsumexp2(e.1, base + p_s);
            }
        }
        // prune to beam
        let mut items: Vec<(Vec<u32>, (f64, f64))> = next.into_iter().collect();
        items.sort_by(|a, b| {
            logsumexp2(b.1 .0, b.1 .1).partial_cmp(&logsumexp2(a.1 .0, a.1 .1)).unwrap()
        });
        items.truncate(beam);
        beams = items.into_iter().collect();
    }
    beams
        .into_iter()
        .max_by(|a, b| {
            logsumexp2(a.1 .0, a.1 .1).partial_cmp(&logsumexp2(b.1 .0, b.1 .1)).unwrap()
        })
        .map(|(p, _)| p)
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// log-softmax a small [t, l] matrix of logits.
    fn lsm(logits: &[f32], l: usize) -> Vec<f32> {
        let mut out = logits.to_vec();
        crate::nn::activation::log_softmax_rows(&mut out, logits.len() / l, l);
        out
    }

    #[test]
    fn greedy_collapses_repeats_and_blanks() {
        // labels: 0=blank, seq of argmaxes: 1 1 0 2 2 0 1 → collapsed 1 2 1
        let l = 3;
        let mk = |id: usize| {
            let mut r = vec![0.0f32; l];
            r[id] = 5.0;
            r
        };
        let rows: Vec<f32> =
            [1, 1, 0, 2, 2, 0, 1].iter().flat_map(|&i| mk(i)).collect();
        let lp = lsm(&rows, l);
        assert_eq!(greedy(&lp, l), vec![1, 2, 1]);
    }

    #[test]
    fn beam_recovers_greedy_on_peaked_posteriors() {
        let l = 4;
        let mk = |id: usize| {
            let mut r = vec![-3.0f32; l];
            r[id] = 6.0;
            r
        };
        let rows: Vec<f32> =
            [1, 0, 2, 0, 3, 3].iter().flat_map(|&i| mk(i)).collect();
        let lp = lsm(&rows, l);
        assert_eq!(prefix_beam(&lp, l, 8), greedy(&lp, l));
    }

    #[test]
    fn beam_beats_greedy_on_ambiguous_case() {
        // Classic case: per-frame argmax is blank everywhere, but the
        // aggregated non-blank mass wins.  p(blank)=0.6/0.6, p(1)=0.4/0.4:
        // best path = [] with p 0.36; prefix [1] has p 0.4*0.6+0.6*0.4+0.4*0.4 = 0.64.
        let l = 2;
        let row = [0.6f32.ln(), 0.4f32.ln()];
        let lp: Vec<f32> = [row, row].concat();
        assert_eq!(greedy(&lp, l), Vec::<u32>::new());
        assert_eq!(prefix_beam(&lp, l, 8), vec![1]);
    }

    #[test]
    fn empty_input() {
        assert!(greedy(&[], 3).is_empty());
        assert!(prefix_beam(&[], 3, 4).is_empty());
    }
}
