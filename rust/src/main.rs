//! `quantasr` CLI — the L3 entrypoint.
//!
//! ```text
//! quantasr table1   --artifacts artifacts [--threads N]
//! quantasr figure2  --artifacts artifacts
//! quantasr eval     --model artifacts/models/p24.qat.qam --mode quant
//!                   [--set eval_clean] [--artifacts artifacts]
//!                   [--isq per-matrix-u8|per-channel-u8|per-channel-i4]
//!                   (in-situ requantization scheme; defaults to
//!                    `QUANTASR_ISQ`, then per-matrix-u8)
//! quantasr serve    --model … --mode quant [--addr 127.0.0.1:7700]
//!                   [--isq <scheme>]  (also applied by 'L'/'S' loads)
//!                   [--max-batch 32] [--deadline-ms 5] [--quantum 25]
//!                   [--max-streams 1024] [--tick-budget 32]
//!                   [--model-weights 4,1] [--model-lanes 32,8]
//!                   [--stream-idle-ms 0] [--stream-deadline-ms 0]
//!                   [--mem-budget-bytes 0] [--trace-out trace.json]
//!                   (stream lifetimes: idle/deadline reaper, 0 =
//!                    disabled; byte budget for arenas + stream
//!                    reservations, 0 = unlimited; hot admin over TCP:
//!                    'L' load / 'U' unload / 'D' bounded unload /
//!                    'S' canaried swap / 'Q' query / 'T' metrics /
//!                    'X' trace export — see docs/PROTOCOL.md; 'L'/'S'
//!                    load .qam paths with the same --mode; --trace-out
//!                    writes the flight-recorder ring as Chrome-trace
//!                    JSON on shutdown — open in Perfetto)
//! quantasr bench-serve --model … [--streams 16] [--utts 64]
//!                   [--trace-out trace.json]
//! quantasr ablate-rounding
//! quantasr ablate-granularity [--model …]
//! quantasr inspect  --model …
//! quantasr pjrt-check --artifacts artifacts   (native vs AOT numerics)
//! ```

use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use quantasr::coordinator::{server, Engine, EngineConfig};
use quantasr::decoder::DecoderConfig;
use quantasr::eval::{build_decoder, evaluate, table1};
use quantasr::io::feat_fmt::read_feats;
use quantasr::io::model_fmt::QamFile;
use quantasr::nn::{AcousticModel, ExecMode};
use quantasr::quant::error as qerror;
use quantasr::quant::QuantScheme;
use quantasr::sim::dataset::{gen_wave, Style};
use quantasr::sim::World;
use quantasr::util::cli::Args;
use quantasr::util::rng::Xoshiro256;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("table1") => cmd_table1(args),
        Some("figure2") => cmd_figure2(args),
        Some("eval") => cmd_eval(args),
        Some("transcribe") => cmd_transcribe(args),
        Some("serve") => cmd_serve(args),
        Some("bench-serve") => cmd_bench_serve(args),
        Some("ablate-rounding") => cmd_ablate_rounding(args),
        Some("ablate-bits") => cmd_ablate_bits(args),
        Some("ablate-granularity") => cmd_ablate_granularity(args),
        Some("inspect") => cmd_inspect(args),
        #[cfg(feature = "pjrt")]
        Some("pjrt-check") => cmd_pjrt_check(args),
        #[cfg(not(feature = "pjrt"))]
        Some("pjrt-check") => {
            bail!("built without the 'pjrt' feature — rebuild with `--features pjrt`")
        }
        Some(other) => bail!("unknown command '{other}' (see src/main.rs docs)"),
        None => {
            println!(
                "quantasr — efficient representation and execution of deep acoustic models\n\
                 commands: table1 figure2 eval serve bench-serve ablate-rounding \
                 ablate-granularity inspect pjrt-check"
            );
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

/// `--isq <scheme>` wins over `QUANTASR_ISQ`; both default to the seed
/// per-matrix-u8 behavior.
fn isq_scheme(args: &Args) -> Result<QuantScheme> {
    match args.get("isq") {
        Some(s) => QuantScheme::parse(s)
            .with_context(|| format!("unknown --isq scheme '{s}' (per-matrix-u8 | per-channel-u8 | per-channel-i4)")),
        None => Ok(QuantScheme::from_env_or_default()),
    }
}

fn threads(args: &Args) -> usize {
    args.get_usize(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    )
}

fn cmd_table1(args: &Args) -> Result<()> {
    let art = artifacts_dir(args);
    let world = World::new();
    let decoder = build_decoder(&world, DecoderConfig::default());
    let rows = table1::run_table1(&art, &decoder, threads(args))?;
    if rows.is_empty() {
        bail!("no trained models found under {}/models — run `make table1`", art.display());
    }
    println!("\nTable 1 (reproduction): WER on clean/noisy eval sets\n");
    println!("{}", table1::format_table(&rows));
    Ok(())
}

fn cmd_figure2(args: &Args) -> Result<()> {
    let art = artifacts_dir(args);
    let curves = quantasr::eval::figure2::load_curves(&art)?;
    println!("{}", quantasr::eval::figure2::format_figure(&curves));
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let art = artifacts_dir(args);
    let model_path = args.get("model").context("--model required")?;
    let mode = ExecMode::parse(args.get_or("mode", "quant"))?;
    let set = args.get_or("set", "eval_clean");
    let utts = read_feats(art.join(format!("data/{set}.feats")))?;
    let model = AcousticModel::load_with_scheme(model_path, mode, isq_scheme(args)?)?;
    let world = World::new();
    let decoder = build_decoder(&world, DecoderConfig::default());
    let r = evaluate(&model, &decoder, &utts, threads(args));
    println!(
        "{model_path} mode={mode:?} set={set}\n  WER {:.2}%  LER {:.2}%  ({} utts, {} frames)\n  \
         AM {:.2}s ({:.1} µs/frame)  decode {:.2}s  storage {} KB",
        100.0 * r.wer,
        100.0 * r.ler,
        r.utts,
        r.frames,
        r.am_seconds,
        1e6 * r.am_seconds / r.frames.max(1) as f64,
        r.decode_seconds,
        model.storage_bytes() / 1024,
    );
    Ok(())
}

fn load_engine(args: &Args) -> Result<Arc<Engine>> {
    let model_path = args.get("model").context("--model required")?;
    let mode = ExecMode::parse(args.get_or("mode", "quant"))?;
    let model = Arc::new(AcousticModel::load_with_scheme(model_path, mode, isq_scheme(args)?)?);
    let world = World::new();
    let decoder = Arc::new(build_decoder(&world, DecoderConfig::default()));
    let mut cfg = EngineConfig::default();
    cfg.apply_cli_flags(args);
    Ok(Arc::new(Engine::start(model, decoder, cfg)))
}

/// Write the engine's flight-recorder ring to `--trace-out` as
/// Chrome-trace JSON (best-effort: a full disk should not fail the run).
fn write_trace_out(args: &Args, engine: &Engine) {
    if let Some(path) = args.get("trace-out") {
        match std::fs::write(path, engine.trace_json()) {
            Ok(()) => println!("wrote trace to {path} (open in Perfetto / chrome://tracing)"),
            Err(e) => eprintln!("warning: could not write trace to {path}: {e}"),
        }
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let addr = args.get_or("addr", "127.0.0.1:7700").to_string();
    let stop = Arc::new(AtomicBool::new(false));
    // Hot-load admin ('L' frames): load .qam paths with the same exec
    // mode and requantization scheme the boot model uses.
    let mode = ExecMode::parse(args.get_or("mode", "quant"))?;
    let scheme = isq_scheme(args)?;
    let loader: server::ModelLoader<AcousticModel> = Arc::new(move |path: &str| {
        Ok(Arc::new(AcousticModel::load_with_scheme(path, mode, scheme)?))
    });
    println!("serving on {addr} (ctrl-c to stop; admin frames: L/U/D/S/Q/T/X)");
    let r = server::serve_with_loader(engine.clone(), &addr, stop, Some(loader), |a| {
        println!("bound {a}")
    });
    write_trace_out(args, &engine);
    r
}

/// In-process serving benchmark: N concurrent synthetic clients.
fn cmd_bench_serve(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let n_streams = args.get_usize("streams", 16);
    let n_utts = args.get_usize("utts", 64);
    let world = Arc::new(World::new());
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for s in 0..n_streams {
            let engine = engine.clone();
            let world = world.clone();
            scope.spawn(move || {
                for u in 0..n_utts.div_ceil(n_streams) {
                    let uid = (s * 1000 + u) as u32;
                    let wave = gen_wave(uid, 0xBE7C, &world, Style::Clean);
                    let (id, rx) = engine.open_stream();
                    // stream in 100 ms chunks
                    for chunk in wave.wave.chunks(800) {
                        engine.push_audio(id, chunk).unwrap();
                    }
                    engine.finish_stream(id).unwrap();
                    let _ = rx.recv().unwrap();
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    println!("bench-serve: {n_streams} streams, ~{n_utts} utts in {wall:.2}s");
    println!("{}", engine.metrics().report());
    write_trace_out(args, &engine);
    Ok(())
}

/// Batch transcription tool: decode a .feats file, print transcripts with
/// N-best alternatives and per-utterance WER.
fn cmd_transcribe(args: &Args) -> Result<()> {
    let art = artifacts_dir(args);
    let model_path = args.get("model").context("--model required")?;
    let mode = ExecMode::parse(args.get_or("mode", "quant"))?;
    let set = args.get_or("set", "eval_clean");
    let nbest = args.get_usize("nbest", 1);
    let limit = args.get_usize("utts", 10);
    let utts = read_feats(art.join(format!("data/{set}.feats")))?;
    let model = AcousticModel::load(model_path, mode)?;
    let world = World::new();
    let decoder = build_decoder(&world, DecoderConfig::default());
    let mut stats = quantasr::decoder::wer::EditStats::default();
    for u in utts.iter().take(limit) {
        let lp = model.forward_utt(&u.feats, u.num_frames);
        let hyps = decoder.decode_nbest(&lp, model.num_labels(), nbest.max(1));
        let best = hyps.first().cloned().unwrap_or_default();
        let st = quantasr::decoder::wer::align(&best.words, &u.words);
        stats.add(&st);
        println!(
            "utt {:>5}  ref {:?}
          hyp {:?}  ({} err)",
            u.uid, u.words, best.words, st.errors()
        );
        for (rank, h) in hyps.iter().enumerate().skip(1) {
            println!(
                "          #{:<2} {:?}  (ac {:.1} lm {:.1})",
                rank + 1, h.words, h.acoustic, h.lm_large
            );
        }
    }
    println!(
        "
WER over {} utts: {:.2}% ({} sub, {} del, {} ins / {} ref words)",
        limit.min(utts.len()),
        100.0 * stats.rate(),
        stats.substitutions,
        stats.deletions,
        stats.insertions,
        stats.ref_len
    );
    Ok(())
}

/// E2: bias error of consistent (eq. 2/3) vs naive quantization.
fn cmd_ablate_rounding(_args: &Args) -> Result<()> {
    let mut rng = Xoshiro256::new(0xE2);
    println!("E2 — rounding-consistency ablation (paper §3, bias vs precision error)\n");
    println!("{:<12} {:>12} {:>12} {:>12} {:>12}", "n", "bias(cons)", "rms(cons)", "bias(naive)", "rms(naive)");
    for n in [256usize, 4096, 65536] {
        let mut v = vec![0f32; n];
        rng.fill_normal(&mut v);
        let c = qerror::stats_consistent(&v);
        let na = qerror::stats_naive(&v);
        println!(
            "{n:<12} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e}",
            c.bias, c.rms, na.bias, na.rms
        );
    }
    println!("\ndot-product error (k=512, 200 trials): |err| consistent vs naive");
    let mut sum = (0.0, 0.0);
    for _ in 0..200 {
        let mut x = vec![0f32; 512];
        let mut w = vec![0f32; 512];
        rng.fill_normal(&mut x);
        rng.fill_normal(&mut w);
        let (c, na) = qerror::dot_bias_experiment(&x, &w);
        sum.0 += c;
        sum.1 += na;
    }
    println!("  mean |err| consistent = {:.4}   naive = {:.4}   ratio = {:.1}x",
        sum.0 / 200.0, sum.1 / 200.0, sum.1 / sum.0.max(1e-12));
    Ok(())
}

/// E5: weight bit-width sweep — post-training quantization at 8/6/5/4/3/2
/// bits, WER on the clean eval set.  Reproduces the resolution-threshold
/// finding the paper cites (Dündar & Rose: ≥10 bits needed without QAT;
/// the paper's point is that 8 bits + their scheme is already enough).
fn cmd_ablate_bits(args: &Args) -> Result<()> {
    let art = artifacts_dir(args);
    let default_model = art.join("models/p24.float.qam");
    let model_path = args.get("model").map(PathBuf::from).unwrap_or(default_model);
    let set = args.get_or("set", "eval_clean");
    let utts = read_feats(art.join(format!("data/{set}.feats")))?;
    let n = args.get_usize("utts", 1024).min(utts.len());
    let world = World::new();
    let decoder = build_decoder(&world, DecoderConfig::default());
    println!("E5 — weight bit-width sweep on {} ({set}, {n} utts)\n", model_path.display());
    let float = AcousticModel::load(&model_path, ExecMode::Float)?;
    let base = evaluate(&float, &decoder, &utts[..n], threads(args));
    println!("{:<8} {:>8} {:>8} {:>12}", "bits", "WER%", "LER%", "rel. loss");
    println!("{:<8} {:>8.2} {:>8.2} {:>12}", "float", 100.0 * base.wer, 100.0 * base.ler, "-");
    for bits in [8u32, 6, 5, 4, 3, 2] {
        let mut m = AcousticModel::load(&model_path, ExecMode::Float)?;
        m.requantize_bits(bits, false);
        let r = evaluate(&m, &decoder, &utts[..n], threads(args));
        println!(
            "{:<8} {:>8.2} {:>8.2} {:>+11.1}%",
            bits,
            100.0 * r.wer,
            100.0 * r.ler,
            100.0 * (r.wer - base.wer) / base.wer.max(1e-9)
        );
    }
    Ok(())
}

/// E3: granularity sweep on a real trained model's matrices.
fn cmd_ablate_granularity(args: &Args) -> Result<()> {
    let art = artifacts_dir(args);
    let default_model = art.join("models/p24.float.qam");
    let model_path = args
        .get("model")
        .map(PathBuf::from)
        .unwrap_or(default_model);
    let qam = QamFile::load(&model_path)?;
    println!("E3 — quantization granularity (paper §3.1) on {}\n", model_path.display());
    println!("{:<10} {:<20} {:>12} {:>12}", "tensor", "granularity", "rms err", "bytes");
    for (name, t) in &qam.tensors {
        let shape = t.shape();
        if shape.len() != 2 {
            continue;
        }
        let w = t.to_f32();
        for (gname, rms, bytes) in qerror::granularity_sweep(&w, shape[0], shape[1]) {
            println!("{name:<10} {gname:<20} {rms:>12.3e} {bytes:>12}");
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let model_path = args.get("model").context("--model required")?;
    let qam = QamFile::load(model_path)?;
    let h = &qam.header;
    println!(
        "{model_path}\n  name={} layers={} cells={} proj={:?} in={} labels={} quantized={} \
         quantize_output={} params={}",
        h.name, h.num_layers, h.cell_dim, h.proj_dim, h.input_dim, h.num_labels,
        h.quantized, h.quantize_output, h.param_count
    );
    println!("  storage: {} KB", qam.storage_bytes() / 1024);
    for (name, t) in &qam.tensors {
        let kind = match t {
            quantasr::io::model_fmt::Tensor::F32 { .. } => "f32",
            quantasr::io::model_fmt::Tensor::U8Q { .. } => "u8q",
        };
        println!("    {name:<10} {kind} {:?}", t.shape());
    }
    Ok(())
}

/// Cross-check native int8 engine vs the AOT/PJRT graph on real frames.
#[cfg(feature = "pjrt")]
fn cmd_pjrt_check(args: &Args) -> Result<()> {
    let art = artifacts_dir(args);
    let utts = read_feats(art.join("data/eval_clean.feats"))?;
    let u = &utts[0];
    let rt = quantasr::runtime::Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    for (variant, qam, mode) in [
        ("float", "p24.float.qam", ExecMode::Float),
        ("quant", "p24.qat.qam", ExecMode::Quant),
    ] {
        let base = art.join(format!("hlo/p24.{variant}.b1"));
        let exe = rt.load_model(&base)?;
        let pjrt_lp = exe.forward_utt(&u.feats, u.num_frames)?;
        let native = AcousticModel::load(art.join("models").join(qam), mode)?;
        let native_lp = native.forward_utt(&u.feats, u.num_frames);
        let max_err = pjrt_lp
            .iter()
            .zip(&native_lp)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("variant {variant:<6} frames={} max |dlogprob| native-vs-pjrt = {max_err:.4}", u.num_frames);
    }
    Ok(())
}
