//! # quantasr
//!
//! A reproduction of *“On the efficient representation and execution of deep
//! acoustic models”* (Alvarez, Prabhavalkar, Bakhtin — Interspeech 2016).
//!
//! The library implements the paper's 8-bit uniform linear quantization
//! scheme (§3), a quantized LSTM acoustic-model inference engine (§3.1), the
//! infrastructure consumed by quantization-aware training (§3.2, training
//! itself lives in `python/compile/train.py`), and the full embedded-ASR
//! substrate the paper evaluates on: an audio frontend, a synthetic speech
//! world, a CTC + lexicon + n-gram-LM decoder, and a streaming serving
//! coordinator.
//!
//! Layers (see DESIGN.md):
//! - **L3 (this crate)** — coordinator, decoder, native int8 engine.
//! - **L2** — JAX model, AOT-lowered to HLO text, executed via [`runtime`].
//! - **L1** — Pallas kernels (build-time; numerics cross-checked in tests).

pub mod coordinator;
pub mod decoder;
pub mod eval;
pub mod frontend;
pub mod io;
pub mod nn;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod util;
