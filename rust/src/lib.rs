//! # quantasr
//!
//! A reproduction of *“On the efficient representation and execution of deep
//! acoustic models”* (Alvarez, Prabhavalkar, Bakhtin — Interspeech 2016).
//!
//! The library implements the paper's 8-bit uniform linear quantization
//! scheme (§3), a quantized LSTM acoustic-model inference engine (§3.1), the
//! infrastructure consumed by quantization-aware training (§3.2, training
//! itself lives in `python/compile/train.py`), and the full embedded-ASR
//! substrate the paper evaluates on: an audio frontend, a synthetic speech
//! world, a CTC + lexicon + n-gram-LM decoder, and a streaming serving
//! coordinator.
//!
//! Layers (see DESIGN.md):
//! - **L3 (this crate)** — coordinator, decoder, native int8 engine.
//! - **L2** — JAX model, AOT-lowered to HLO text, executed via [`runtime`]
//!   (feature `pjrt`).
//! - **L1** — Pallas kernels (build-time; numerics cross-checked in tests).
//!
//! ## Serving architecture: `AmBackend` + `BatchArena`
//!
//! The streaming coordinator ([`coordinator::engine`]) is generic over the
//! [`runtime::AmBackend`] trait — the single, lane-resident execution
//! interface that both the native engine ([`nn::AcousticModel`]) and the
//! PJRT/AOT path (`runtime::model_exec::ModelExecutable`, feature `pjrt`)
//! implement, so swapping execution backends is a one-line change at
//! `Engine::start`.
//!
//! State lives in a persistent [`nn::model::BatchArena`]: each live stream
//! owns a stable *lane* in pre-allocated `[max_batch, state]` buffers and
//! every batched tick steps the active lanes **in place**
//! ([`nn::AcousticModel::arena_step`], lane-masked GEMM entry points in
//! [`quant::gemm`]).  There is no per-tick gather/scatter of recurrent
//! state; idle streams can be evicted (state parked on the stream, lane
//! handed to a waiter) and restored exactly.  Per-row input quantization
//! makes a lane's numerics bit-identical to running its stream alone, so
//! batching and lane placement are invisible to results.
//!
//! ## Scheduling: preemptive multi-model lane placement
//!
//! Lane-placement *policy* lives in [`sched`], separate from the engine's
//! mechanism: time-sliced preemption (every admitted stream gets a tick
//! quantum; exhausted holders are preempted through the exact
//! `save_lane`/`load_lane` parking path, so newcomers' wait is bounded
//! even under full saturation), QoS classes ([`sched::Priority`]) feeding
//! victim selection and batch-formation order, bounded admission with
//! reject-with-reason backpressure ([`sched::admission`]), and a
//! multi-model registry ([`sched::ModelRegistry`]) that serves N loaded
//! models through one scheduler, AM worker and decode pool with per-model
//! lane accounting.  Preemption never changes numerics — it only decides
//! *when* a stream's frames are computed.
//!
//! ## Integer GEMM: packed panels + kernel ladder
//!
//! The paper's "optimized hardware instructions for integer arithmetic"
//! claim is realized in [`quant::gemm`]: every PerMatrix-quantized weight
//! matrix is repacked **once at load** into a [`quant::PackedQMatrix`] —
//! K-interleaved panels of 4 output rows — so the register-blocked
//! microkernels load each input chunk once per 4 outputs and stream the
//! matrix sequentially.  The microkernel is runtime-dispatched (AVX2
//! `madd_epi16`; AVX-512-VNNI `vpdpbusd` behind the `vnni` cargo feature;
//! NEON `dot` on aarch64; scalar reference elsewhere) and large GEMMs
//! parallelize across panels on the persistent [`util::pool::WorkerPool`]
//! (parked workers, no per-call spawn — batch-1 GEMVs fan out too).
//! Every rung — and every thread split — is **bit-identical** to the
//! scalar reference (property-tested for all K tails, panel remainders
//! and lane subsets), so the serving engine's batch-invariance guarantee
//! is preserved verbatim.
//!
//! ## Vectorized elementwise path
//!
//! Everything around the GEMMs is vectorized too ([`quant::elementwise`]):
//! the LSTM gate nonlinearities + cell update run as one fused SIMD pass
//! (polynomial sigmoid/tanh with a scalar reference that every rung
//! matches **bit-for-bit**, and that stays within a documented 1e-6 of
//! libm), and per-row activation quantization uses a SIMD min/max +
//! quantize scan with a per-layer cache ([`quant::gemm::QActRows`]) so a
//! layer output consumed by two quantized GEMMs is quantized once.
//!
//! ## Observability: the flight-recorder trace plane
//!
//! Aggregate metrics ([`coordinator::metrics`], bounded log-bucketed
//! histograms exposed over the `'T'` admin frame) say *how much*; the
//! always-on flight recorder ([`obs`]) says *which streams, ticks and
//! decode jobs* — lock-free per-thread seqlock rings of structured
//! events covering the whole stream lifecycle, exported as
//! Chrome-trace/Perfetto JSON (`--trace-out`, the `'X'` admin frame)
//! and frozen into bounded postmortem dumps on panic quarantine,
//! brownout entry and forced cancels.  Every admission carries a trace
//! id that is echoed in the stream's terminal wire frames so client
//! logs join server traces (`docs/PROTOCOL.md`).

pub mod coordinator;
pub mod decoder;
pub mod eval;
pub mod frontend;
pub mod io;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod util;
