//! Figure 2 regeneration: dev LER vs training time for the three CTC
//! learning-rate schedules (§5.1), from the CSV curves exported by
//! `python -m compile.train --preset figure2`.

use std::path::Path;

use anyhow::{Context, Result};

/// One curve: (wall seconds, step, dev LER).
#[derive(Clone, Debug)]
pub struct Curve {
    pub name: String,
    pub points: Vec<(f64, u64, f64)>,
}

pub const SCHEDULES: &[&str] = &["low_lr", "svd_init", "sched_proj"];

pub fn load_curves(artifacts: &Path) -> Result<Vec<Curve>> {
    let mut out = Vec::new();
    for name in SCHEDULES {
        let path = artifacts.join(format!("curves/figure2_{name}.csv"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("{} (run `make figure2` first)", path.display()))?;
        let mut points = Vec::new();
        for line in text.lines().skip(1) {
            let mut it = line.split(',');
            let wall: f64 = it.next().unwrap_or("0").parse()?;
            let step: u64 = it.next().unwrap_or("0").parse()?;
            let ler: f64 = it.next().unwrap_or("1").parse()?;
            points.push((wall, step, ler));
        }
        out.push(Curve { name: name.to_string(), points });
    }
    Ok(out)
}

/// ASCII rendering of the three curves (LER vs wall time), plus the final
/// values — the textual analogue of the paper's Figure 2.
pub fn format_figure(curves: &[Curve]) -> String {
    let mut out = String::new();
    out.push_str("Figure 2: dev label error rate vs training time (CTC, projection model)\n\n");
    let t_max = curves
        .iter()
        .flat_map(|c| c.points.last().map(|p| p.0))
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let rows = 16;
    let cols = 64;
    // grid[r][c] = char
    let mut grid = vec![vec![b' '; cols]; rows];
    let ler_max = 1.0f64;
    for (ci, c) in curves.iter().enumerate() {
        let ch = [b'*', b'o', b'+'][ci % 3];
        for &(wall, _step, ler) in &c.points {
            let x = ((wall / t_max) * (cols - 1) as f64) as usize;
            let y = ((ler / ler_max) * (rows - 1) as f64).min((rows - 1) as f64) as usize;
            let y = rows - 1 - y;
            grid[y][x.min(cols - 1)] = ch;
        }
    }
    out.push_str("LER 1.0 ┤\n");
    for (i, row) in grid.iter().enumerate() {
        let label = if i == rows - 1 { "LER 0.0 " } else { "        " };
        out.push_str(label);
        out.push('│');
        out.push_str(std::str::from_utf8(row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!(
        "        └{} t={:.0}s\n",
        "─".repeat(cols),
        t_max
    ));
    out.push_str("legend: * low_lr   o svd_init   + sched_proj\n\n");
    for c in curves {
        if let Some(&(wall, step, ler)) = c.points.last() {
            let best = c
                .points
                .iter()
                .map(|p| p.2)
                .fold(f64::INFINITY, f64::min);
            out.push_str(&format!(
                "{:<12} final LER {:.3} (best {:.3}) after {} steps / {:.0}s\n",
                c.name, ler, best, step, wall
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_renders_without_curves_present() {
        let curves = vec![
            Curve {
                name: "low_lr".into(),
                points: vec![(0.0, 1, 1.0), (10.0, 100, 0.8), (20.0, 200, 0.7)],
            },
            Curve {
                name: "svd_init".into(),
                points: vec![(5.0, 1, 0.9), (20.0, 200, 0.3)],
            },
            Curve {
                name: "sched_proj".into(),
                points: vec![(0.0, 1, 1.0), (20.0, 200, 0.15)],
            },
        ];
        let s = format_figure(&curves);
        assert!(s.contains("legend"));
        assert!(s.contains("sched_proj"));
        assert!(s.contains("final LER 0.150"));
    }
}
