//! Evaluation harnesses that regenerate the paper's tables and figures.
//!
//! - [`wer_eval`] — dataset → WER/LER under an execution mode (the core
//!   measurement behind Table 1).
//! - [`table1`]   — the full Table-1 grid: {match, mismatch, quant,
//!   quant-all} × architectures × {clean, noisy}.
//! - [`figure2`]  — formats the LR-schedule LER curves exported by
//!   `python -m compile.train --preset figure2`.

pub mod figure2;
pub mod table1;
pub mod wer_eval;

pub use wer_eval::{build_decoder, evaluate, EvalResult};
