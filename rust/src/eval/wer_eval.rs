//! WER evaluation: run a model over a `.feats` split, decode, score.

use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;

use crate::decoder::lm::NGramLm;
use crate::decoder::trie::LexTrie;
use crate::decoder::{ctc, wer, Decoder, DecoderConfig};
use crate::io::feat_fmt::Utt;
use crate::nn::{AcousticModel, ExecMode};
use crate::sim::dataset::text_corpus;
use crate::sim::World;

/// Aggregate evaluation result on one split.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub wer: f64,
    pub ler: f64,
    pub utts: usize,
    pub frames: usize,
    /// AM forward seconds (all utterances, batch 1).
    pub am_seconds: f64,
    pub decode_seconds: f64,
}

/// Build the standard decoder (lexicon trie + small/large LMs) from the
/// shared world.  LM training text is a fixed 20k-sentence corpus.
pub fn build_decoder(world: &World, config: DecoderConfig) -> Decoder {
    let corpus = text_corpus(20_000, 0xC0_0C, world);
    let trie = LexTrie::from_world(world);
    let lm_small = NGramLm::small(&corpus, world.lexicon.len());
    let lm_large = NGramLm::large(&corpus, world.lexicon.len());
    Decoder::new(trie, lm_small, lm_large, config)
}

/// Evaluate a model on a set of utterances (multi-threaded over utts).
pub fn evaluate(
    model: &AcousticModel,
    decoder: &Decoder,
    utts: &[Utt],
    threads: usize,
) -> EvalResult {
    let next = std::sync::atomic::AtomicUsize::new(0);
    let acc = Mutex::new((wer::EditStats::default(), wer::EditStats::default(), 0.0f64, 0.0f64, 0usize));
    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= utts.len() {
                    break;
                }
                let u = &utts[i];
                let t0 = std::time::Instant::now();
                let lp = model.forward_utt(&u.feats, u.num_frames);
                let am_dt = t0.elapsed().as_secs_f64();
                let t1 = std::time::Instant::now();
                let hyp = decoder.decode(&lp, model.num_labels());
                let phones = ctc::greedy(&lp, model.num_labels());
                let dec_dt = t1.elapsed().as_secs_f64();
                let w_st = wer::align(&hyp.words, &u.words);
                let l_st = wer::align(&phones, &u.phones);
                let mut g = acc.lock().unwrap();
                g.0.add(&w_st);
                g.1.add(&l_st);
                g.2 += am_dt;
                g.3 += dec_dt;
                g.4 += u.num_frames;
            });
        }
    });
    let g = acc.into_inner().unwrap();
    EvalResult {
        wer: g.0.rate(),
        ler: g.1.rate(),
        utts: utts.len(),
        frames: g.4,
        am_seconds: g.2,
        decode_seconds: g.3,
    }
}

/// Load a `.qam` under a mode and evaluate a split file.
pub fn evaluate_model_file(
    qam: impl AsRef<Path>,
    mode: ExecMode,
    utts: &[Utt],
    decoder: &Decoder,
    threads: usize,
) -> Result<EvalResult> {
    let model = AcousticModel::load(qam, mode)?;
    Ok(evaluate(&model, decoder, utts, threads))
}
