//! Table 1 regeneration: WER on clean/noisy eval sets for every
//! architecture under the four conditions.
//!
//! Models come from `python -m compile.train --preset table1` (or just the
//! quickstart model when only that was trained):
//! `<name>.float.qam` → 'match' (f32 eval) and 'mismatch' (quantized eval);
//! `<name>.qat.qam` → 'quant'; `<name>.qatall.qam` → 'quant-all'.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::decoder::Decoder;
use crate::eval::wer_eval::{evaluate_model_file, EvalResult};
use crate::io::feat_fmt::{read_feats, Utt};
use crate::nn::ExecMode;

/// WERs for one architecture on one eval set.
#[derive(Clone, Copy, Debug, Default)]
pub struct Row {
    pub matched: f64,
    pub mismatch: f64,
    pub quant: f64,
    pub quant_all: f64,
}

impl Row {
    fn rel(&self, v: f64) -> f64 {
        if self.matched <= 0.0 {
            0.0
        } else {
            100.0 * (v - self.matched) / self.matched
        }
    }
}

/// Everything measured for one architecture.
#[derive(Clone, Debug)]
pub struct ArchResult {
    pub name: String,
    pub param_count: usize,
    pub clean: Row,
    pub noisy: Row,
}

/// The architecture names of the Table-1 grid, in paper order
/// (must match `model.py::TABLE1_CONFIGS` names).
pub const TABLE1_ARCHS: &[&str] = &[
    "4x30", "5x30", "4x40", "5x40", "4x50", "5x50", "p10", "p20", "p30", "p40",
];

/// Evaluate one architecture (4 model-file × mode combinations × 2 sets).
pub fn eval_arch(
    models_dir: &Path,
    name: &str,
    clean: &[Utt],
    noisy: &[Utt],
    decoder: &Decoder,
    threads: usize,
) -> Result<ArchResult> {
    let float_qam = models_dir.join(format!("{name}.float.qam"));
    let qat_qam = models_dir.join(format!("{name}.qat.qam"));
    let qatall_qam = models_dir.join(format!("{name}.qatall.qam"));
    let header = crate::io::model_fmt::QamFile::load(&float_qam)
        .with_context(|| format!("loading {}", float_qam.display()))?
        .header;

    let run = |qam: &PathBuf, mode: ExecMode, utts: &[Utt]| -> Result<EvalResult> {
        evaluate_model_file(qam, mode, utts, decoder, threads)
    };
    let mut result = ArchResult {
        name: name.to_string(),
        param_count: header.param_count,
        clean: Row::default(),
        noisy: Row::default(),
    };
    for (set, utts) in [("clean", clean), ("noisy", noisy)] {
        let row = Row {
            matched: run(&float_qam, ExecMode::Float, utts)?.wer,
            mismatch: run(&float_qam, ExecMode::Quant, utts)?.wer,
            quant: run(&qat_qam, ExecMode::Quant, utts)?.wer,
            quant_all: run(&qatall_qam, ExecMode::QuantAll, utts)?.wer,
        };
        if set == "clean" {
            result.clean = row;
        } else {
            result.noisy = row;
        }
    }
    Ok(result)
}

/// Run the full table over whatever architectures have model files.
pub fn run_table1(artifacts: &Path, decoder: &Decoder, threads: usize) -> Result<Vec<ArchResult>> {
    let clean = read_feats(artifacts.join("data/eval_clean.feats"))?;
    let noisy = read_feats(artifacts.join("data/eval_noisy.feats"))?;
    let models = artifacts.join("models");
    let mut rows = Vec::new();
    // include the quickstart model name if present but not in the grid
    let mut archs: Vec<String> = TABLE1_ARCHS.iter().map(|s| s.to_string()).collect();
    archs.push("p24".to_string());
    for name in archs {
        if !models.join(format!("{name}.float.qam")).exists() {
            continue;
        }
        eprintln!("[table1] evaluating {name} …");
        rows.push(eval_arch(&models, &name, &clean, &noisy, decoder, threads)?);
    }
    Ok(rows)
}

/// Format in the paper's layout (WER % with relative loss in parens).
pub fn format_table(rows: &[ArchResult]) -> String {
    let mut out = String::new();
    out.push_str(
        "| System (Params.) | Clean: match | mismatch | quant | quant-all | Noisy: match | mismatch | quant | quant-all |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|---|---|\n");
    let cell = |r: &Row, v: f64| format!("{:.1} ({:+.1}%)", 100.0 * v, r.rel(v));
    let mut avg = [0.0f64; 6]; // rel losses: clean mm/q/qa, noisy mm/q/qa
    for a in rows {
        out.push_str(&format!(
            "| {} (~{}K) | {:.1} | {} | {} | {} | {:.1} | {} | {} | {} |\n",
            a.name,
            a.param_count / 1000,
            100.0 * a.clean.matched,
            cell(&a.clean, a.clean.mismatch),
            cell(&a.clean, a.clean.quant),
            cell(&a.clean, a.clean.quant_all),
            100.0 * a.noisy.matched,
            cell(&a.noisy, a.noisy.mismatch),
            cell(&a.noisy, a.noisy.quant),
            cell(&a.noisy, a.noisy.quant_all),
        ));
        avg[0] += a.clean.rel(a.clean.mismatch);
        avg[1] += a.clean.rel(a.clean.quant);
        avg[2] += a.clean.rel(a.clean.quant_all);
        avg[3] += a.noisy.rel(a.noisy.mismatch);
        avg[4] += a.noisy.rel(a.noisy.quant);
        avg[5] += a.noisy.rel(a.noisy.quant_all);
    }
    let n = rows.len().max(1) as f64;
    out.push_str(&format!(
        "| Avg. relative loss | – | {:+.1}% | {:+.1}% | {:+.1}% | – | {:+.1}% | {:+.1}% | {:+.1}% |\n",
        avg[0] / n, avg[1] / n, avg[2] / n, avg[3] / n, avg[4] / n, avg[5] / n
    ));
    out
}
