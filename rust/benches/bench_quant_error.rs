//! E2/E3 — quantization quality + cost benches:
//! quantize/recover throughput, the rounding-consistency (bias) ablation,
//! and the granularity sweep error/storage trade-off.

use quantasr::quant::error::{dot_bias_experiment, granularity_sweep, stats_consistent, stats_naive};
use quantasr::quant::scheme::QuantParams;
use quantasr::util::bench::Bench;
use quantasr::util::rng::Xoshiro256;

fn main() {
    let b = Bench::default();
    let mut rng = Xoshiro256::new(0xE23);

    println!("== bench_quant_error: quantize/recover throughput ==");
    for n in [4096usize, 65536, 1 << 20] {
        let mut v = vec![0f32; n];
        rng.fill_normal(&mut v);
        let p = QuantParams::from_slice(&v);
        let mut q = vec![0u8; n];
        let mut r = vec![0f32; n];
        b.run_with_items(&format!("quantize eq.2   n={n}"), n as f64, || {
            p.quantize_slice(&v, &mut q)
        });
        b.run_with_items(&format!("recover  eq.3   n={n}"), n as f64, || {
            p.recover_slice(&q, &mut r)
        });
        b.run_with_items(&format!("derive params   n={n}"), n as f64, || {
            QuantParams::from_slice(&v)
        });
    }

    println!("\n== E2: bias of consistent vs naive scheme (N(0,1) values) ==");
    println!("{:<10} {:>13} {:>11} {:>13} {:>11}", "n", "bias(eq2/3)", "rms", "bias(naive)", "rms");
    for n in [1024usize, 16384, 262144] {
        let mut v = vec![0f32; n];
        rng.fill_normal(&mut v);
        let c = stats_consistent(&v);
        let na = stats_naive(&v);
        println!(
            "{n:<10} {:>13.3e} {:>11.3e} {:>13.3e} {:>11.3e}",
            c.bias, c.rms, na.bias, na.rms
        );
    }
    println!("\ndot-product |error| (mean over 300 trials):");
    for k in [50usize, 200, 800] {
        let (mut cs, mut ns) = (0.0, 0.0);
        for _ in 0..300 {
            let mut x = vec![0f32; k];
            let mut w = vec![0f32; k];
            rng.fill_normal(&mut x);
            rng.fill_normal(&mut w);
            let (c, na) = dot_bias_experiment(&x, &w);
            cs += c;
            ns += na;
        }
        println!(
            "  k={k:<5} consistent {:.4}  naive {:.4}  ({:.1}× worse)",
            cs / 300.0,
            ns / 300.0,
            ns / cs.max(1e-12)
        );
    }

    println!("\n== E3: granularity sweep (512×512 heterogeneous matrix) ==");
    // Rows with 10× magnitude spread — the case finer granularity helps.
    let (in_dim, out_dim) = (512usize, 512usize);
    let mut w = vec![0f32; in_dim * out_dim];
    rng.fill_normal(&mut w);
    for o in 0..out_dim {
        let gain = 0.2 + 3.0 * (o as f32 / out_dim as f32);
        for i in 0..in_dim {
            w[i * out_dim + o] *= gain;
        }
    }
    println!("{:<22} {:>12} {:>12}", "granularity", "rms err", "KB");
    for (name, rms, bytes) in granularity_sweep(&w, in_dim, out_dim) {
        println!("{name:<22} {rms:>12.3e} {:>12}", bytes / 1024);
    }
}
