//! E1 — LSTM(P) layer step: float vs quantized execution across the
//! Table-1 architecture family and batch sizes, plus the **elementwise
//! ladder**: how much of a step the gate nonlinearities + cell update
//! cost, and what the fused SIMD kernel buys over the old scalar libm
//! loop (the PR-3 acceptance bar: fused ≥ 3× the libm loop at batch 32).
//!
//! Results land in `BENCH_lstm.json` (CI uploads it) with three sections:
//! `steps` (whole-step times), `elementwise` (isolated cell-update rungs:
//! libm-loop baseline, scalar polynomial reference, fused auto), and
//! `splits` (per-step GEMM vs elementwise share).
//!
//! Env knobs: `QUANTASR_KERNEL` / `QUANTASR_EW_KERNEL` force rungs,
//! `QUANTASR_GEMM_THREADS=1` pins the GEMMs serial.

use std::fmt::Write as _;

use quantasr::io::model_fmt::Tensor;
use quantasr::nn::linear::Linear;
use quantasr::nn::lstm::{LstmLayer, LstmScratch};
use quantasr::quant::elementwise::{lstm_cell_batch, EwKernel};
use quantasr::quant::gemm::Kernel;
use quantasr::util::bench::{Bench, Measurement};
use quantasr::util::rng::Xoshiro256;

fn linear(i: usize, o: usize, rng: &mut Xoshiro256) -> Linear {
    let mut data = vec![0f32; i * o];
    rng.fill_normal(&mut data);
    for v in data.iter_mut() {
        *v *= (1.0 / i as f32).sqrt();
    }
    Linear::from_tensor(&Tensor::F32 { shape: vec![i, o], data }).unwrap()
}

fn layer(in_dim: usize, n: usize, p: Option<usize>, rng: &mut Xoshiro256) -> LstmLayer {
    LstmLayer {
        wx: linear(in_dim, 4 * n, rng),
        wh: linear(p.unwrap_or(n), 4 * n, rng),
        bias: vec![0.0; 4 * n],
        wp: p.map(|pp| linear(n, pp, rng)),
        cell_dim: n,
    }
}

fn quantize(l: &LstmLayer) -> LstmLayer {
    LstmLayer {
        wx: l.wx.quantize_now(),
        wh: l.wh.quantize_now(),
        bias: l.bias.clone(),
        wp: l.wp.as_ref().map(Linear::quantize_now),
        cell_dim: l.cell_dim,
    }
}

/// The pre-PR-3 scalar elementwise loop (libm sigmoid/tanh, stash +
/// copy), kept here verbatim as the baseline the fused kernel is
/// measured against.
fn libm_cell_loop(gates: &mut [f32], c: &mut [f32], h: &mut [f32], batch: usize, n: usize) {
    let sig = |x: f32| {
        if x >= 0.0 {
            1.0 / (1.0 + (-x).exp())
        } else {
            let z = x.exp();
            z / (1.0 + z)
        }
    };
    for bi in 0..batch {
        let g = &mut gates[bi * 4 * n..(bi + 1) * 4 * n];
        let cr = &mut c[bi * n..(bi + 1) * n];
        for j in 0..n {
            let i_g = sig(g[j]);
            let f_g = sig(g[n + j]);
            let g_g = g[2 * n + j].tanh();
            let o_g = sig(g[3 * n + j]);
            let c_new = f_g * cr[j] + i_g * g_g;
            cr[j] = c_new;
            g[j] = o_g * c_new.tanh();
        }
    }
    for bi in 0..batch {
        let src = &gates[bi * 4 * n..bi * 4 * n + n];
        h[bi * n..(bi + 1) * n].copy_from_slice(src);
    }
}

struct Row {
    section: &'static str,
    arch: String,
    batch: usize,
    variant: String,
    m: Measurement,
}

fn find_ns(rows: &[Row], section: &str, arch: &str, batch: usize, variant: &str) -> Option<f64> {
    rows.iter()
        .find(|r| {
            r.section == section && r.arch == arch && r.batch == batch && r.variant == variant
        })
        .map(|r| r.m.mean_ns)
}

fn main() {
    let b = Bench::default();
    let mut rng = Xoshiro256::new(0x15F);
    let mut rows: Vec<Row> = Vec::new();
    println!("== bench_lstm: LSTMP step float vs int8 (E1) + elementwise ladder ==");

    // (name, N, P) from the Table-1 grid (input dim 64 as in the models).
    let archs: &[(&str, usize, Option<usize>)] = &[
        ("N=30", 30, None),
        ("N=50", 50, None),
        ("N=50,P=20", 50, Some(20)),
        ("N=200", 200, None),
        ("N=500,P=200", 500, Some(200)),
    ];
    for &(name, n, p) in archs {
        for batch in [1usize, 8, 32] {
            let lf = layer(64, n, p, &mut rng);
            let lq = quantize(&lf);
            let mut x = vec![0f32; batch * 64];
            rng.fill_normal(&mut x);
            let mut st_f = lf.zero_state(batch);
            let mut st_q = lq.zero_state(batch);
            let mut s = LstmScratch::default();
            let m_f = b.run_with_items(&format!("lstm f32  {name} b{batch}"), batch as f64, || {
                lf.step(&x, batch, &mut st_f, &mut s, Kernel::Auto)
            });
            let m_q = b.run_with_items(&format!("lstm int8 {name} b{batch}"), batch as f64, || {
                lq.step(&x, batch, &mut st_q, &mut s, Kernel::Auto)
            });
            let speedup = m_f.mean_ns / m_q.mean_ns;
            println!("  → int8 speedup (auto = packed dispatch) {speedup:.2}×\n");
            rows.push(Row {
                section: "steps",
                arch: name.into(),
                batch,
                variant: "f32".into(),
                m: m_f,
            });
            rows.push(Row {
                section: "steps",
                arch: name.into(),
                batch,
                variant: "int8-auto".into(),
                m: m_q,
            });
        }
    }

    // Isolated elementwise cell update: old libm loop vs the polynomial
    // scalar reference vs the fused SIMD rung (auto dispatch).  This is
    // the PR-3 acceptance measurement — at batch 32 the fused rung must
    // be ≥ 3× the libm loop.
    println!("== elementwise cell update: libm loop vs scalar ref vs fused ==");
    for &(name, n, _p) in archs {
        for batch in [1usize, 8, 32] {
            let mut gates = vec![0f32; batch * 4 * n];
            rng.fill_normal(&mut gates);
            for v in gates.iter_mut() {
                *v *= 2.0;
            }
            let mut c = vec![0f32; batch * n];
            let mut h = vec![0f32; batch * n];
            // The old loop mutates its gate buffer (the stash slot), so it
            // gets its own copy; no restore inside the timed closure — the
            // baseline must pay exactly what the old hot path paid, or the
            // fused-vs-libm acceptance ratio would be inflated.  (libm
            // sigmoid/tanh cost is input-value-independent, so iterating
            // on the mutated buffer does not skew the measurement.)
            let mut gates_libm = gates.clone();
            let m_libm = b.run_with_items(
                &format!("ew libm-loop  {name} b{batch}"),
                (batch * n) as f64,
                || libm_cell_loop(&mut gates_libm, &mut c, &mut h, batch, n),
            );
            let m_scalar = b.run_with_items(
                &format!("ew scalar-ref {name} b{batch}"),
                (batch * n) as f64,
                || lstm_cell_batch(&gates, &mut c, &mut h, batch, n, EwKernel::Scalar),
            );
            let m_fused = b.run_with_items(
                &format!("ew fused-auto {name} b{batch}"),
                (batch * n) as f64,
                || lstm_cell_batch(&gates, &mut c, &mut h, batch, n, EwKernel::Auto),
            );
            println!(
                "  → fused vs libm-loop {:.2}×, vs scalar-ref {:.2}×\n",
                m_libm.mean_ns / m_fused.mean_ns,
                m_scalar.mean_ns / m_fused.mean_ns
            );
            for (variant, m) in [
                ("libm-loop", m_libm),
                ("scalar-ref", m_scalar),
                ("fused-auto", m_fused),
            ] {
                rows.push(Row {
                    section: "elementwise",
                    arch: name.into(),
                    batch,
                    variant: variant.into(),
                    m,
                });
            }
        }
    }

    // Emit BENCH_lstm.json: raw rows + per-(arch, batch) split of a step
    // into GEMM and elementwise time, and the fused-vs-libm speedup.
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"lstm\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"section\": \"{}\", \"arch\": \"{}\", \"batch\": {}, \
             \"variant\": \"{}\", \"mean_ns\": {:.1}, \"p50_ns\": {:.1}, \
             \"p99_ns\": {:.1}}}{comma}",
            r.section, r.arch, r.batch, r.variant, r.m.mean_ns, r.m.p50_ns, r.m.p99_ns,
        );
    }
    json.push_str("  ],\n  \"splits\": [\n");
    let mut lines: Vec<String> = Vec::new();
    for &(name, _n, _p) in archs {
        for batch in [1usize, 8, 32] {
            let (Some(step_ns), Some(ew_ns), Some(libm_ns)) = (
                find_ns(&rows, "steps", name, batch, "int8-auto"),
                find_ns(&rows, "elementwise", name, batch, "fused-auto"),
                find_ns(&rows, "elementwise", name, batch, "libm-loop"),
            ) else {
                continue;
            };
            lines.push(format!(
                "    {{\"arch\": \"{name}\", \"batch\": {batch}, \
                 \"step_ns\": {step_ns:.1}, \"elementwise_ns\": {ew_ns:.1}, \
                 \"elementwise_share\": {:.4}, \"fused_vs_libm_loop\": {:.3}}}",
                ew_ns / step_ns,
                libm_ns / ew_ns
            ));
        }
    }
    json.push_str(&lines.join(",\n"));
    json.push_str("\n  ]\n}\n");
    match std::fs::write("BENCH_lstm.json", &json) {
        Ok(()) => println!("\nwrote BENCH_lstm.json"),
        Err(e) => eprintln!("\ncould not write BENCH_lstm.json: {e}"),
    }
}
