//! E1 — LSTM(P) layer step: float vs quantized execution across the
//! Table-1 architecture family and batch sizes.

use quantasr::io::model_fmt::Tensor;
use quantasr::nn::linear::Linear;
use quantasr::nn::lstm::{LstmLayer, LstmScratch};
use quantasr::quant::gemm::Kernel;
use quantasr::util::bench::Bench;
use quantasr::util::rng::Xoshiro256;

fn linear(i: usize, o: usize, rng: &mut Xoshiro256) -> Linear {
    let mut data = vec![0f32; i * o];
    rng.fill_normal(&mut data);
    for v in data.iter_mut() {
        *v *= (1.0 / i as f32).sqrt();
    }
    Linear::from_tensor(&Tensor::F32 { shape: vec![i, o], data }).unwrap()
}

fn layer(in_dim: usize, n: usize, p: Option<usize>, rng: &mut Xoshiro256) -> LstmLayer {
    LstmLayer {
        wx: linear(in_dim, 4 * n, rng),
        wh: linear(p.unwrap_or(n), 4 * n, rng),
        bias: vec![0.0; 4 * n],
        wp: p.map(|pp| linear(n, pp, rng)),
        cell_dim: n,
    }
}

fn quantize(l: &LstmLayer) -> LstmLayer {
    LstmLayer {
        wx: l.wx.quantize_now(),
        wh: l.wh.quantize_now(),
        bias: l.bias.clone(),
        wp: l.wp.as_ref().map(Linear::quantize_now),
        cell_dim: l.cell_dim,
    }
}

fn main() {
    let b = Bench::default();
    let mut rng = Xoshiro256::new(0x15F);
    println!("== bench_lstm: LSTMP step float vs int8 (E1) ==");

    // (name, N, P) from the Table-1 grid (input dim 64 as in the models).
    let archs: &[(&str, usize, Option<usize>)] = &[
        ("N=30", 30, None),
        ("N=50", 50, None),
        ("N=50,P=20", 50, Some(20)),
        ("N=200", 200, None),
        ("N=500,P=200", 500, Some(200)),
    ];
    for &(name, n, p) in archs {
        for batch in [1usize, 8, 32] {
            let lf = layer(64, n, p, &mut rng);
            let lq = quantize(&lf);
            let mut x = vec![0f32; batch * 64];
            rng.fill_normal(&mut x);
            let mut st_f = lf.zero_state(batch);
            let mut st_q = lq.zero_state(batch);
            let mut s = LstmScratch::default();
            let m_f = b.run_with_items(&format!("lstm f32  {name} b{batch}"), batch as f64, || {
                lf.step(&x, batch, &mut st_f, &mut s, Kernel::Auto)
            });
            let m_q = b.run_with_items(&format!("lstm int8 {name} b{batch}"), batch as f64, || {
                lq.step(&x, batch, &mut st_q, &mut s, Kernel::Auto)
            });
            let speedup = m_f.mean_ns / m_q.mean_ns;
            println!("  → int8 speedup (auto = packed dispatch) {speedup:.2}×\n");
        }
    }

    // Packed-panel vs the old row-dot rung through a full recurrent step,
    // at the paper-scale width (the LSTM-level view of bench_gemm's gate).
    #[cfg(target_arch = "x86_64")]
    if quantasr::quant::gemm::avx2_available() {
        println!("== lstm step: avx2 row-dot vs packed panels (N=500,P=200) ==");
        for batch in [1usize, 8, 32] {
            let lq = quantize(&layer(64, 500, Some(200), &mut rng));
            let mut x = vec![0f32; batch * 64];
            rng.fill_normal(&mut x);
            let mut st = lq.zero_state(batch);
            let mut s = LstmScratch::default();
            let m_rowdot =
                b.run_with_items(&format!("lstm int8 rowdot b{batch}"), batch as f64, || {
                    lq.step(&x, batch, &mut st, &mut s, Kernel::Avx2)
                });
            let m_packed =
                b.run_with_items(&format!("lstm int8 packed b{batch}"), batch as f64, || {
                    lq.step(&x, batch, &mut st, &mut s, Kernel::PackedAvx2)
                });
            println!("  → packed vs rowdot {:.2}×\n", m_rowdot.mean_ns / m_packed.mean_ns);
        }
    }
}
