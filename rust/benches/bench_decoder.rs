//! Decoder benches: word beam search cost vs beam width, phone prefix beam,
//! n-gram LM scoring throughput, WER scoring.  (§4 decoding setup; the
//! decoder shares the embedded real-time budget with the AM.)

use quantasr::decoder::lm::NGramLm;
use quantasr::decoder::trie::LexTrie;
use quantasr::decoder::{ctc, wer, Decoder, DecoderConfig};
use quantasr::sim::dataset::text_corpus;
use quantasr::sim::World;
use quantasr::util::bench::Bench;
use quantasr::util::rng::Xoshiro256;

/// Synthetic peaked posteriors for a random in-lexicon word sequence.
fn posteriors(world: &World, n_words: usize, rng: &mut Xoshiro256) -> (Vec<f32>, usize) {
    let labels = quantasr::frontend::spec::N_LABELS;
    let mut rows: Vec<f32> = Vec::new();
    let mut push = |id: u32, rng: &mut Xoshiro256| {
        let mut r = vec![0f32; labels];
        for v in r.iter_mut() {
            *v = rng.normal() as f32 * 0.3 - 6.0;
        }
        r[id as usize] = -0.05;
        rows.extend(r);
    };
    push(0, rng);
    for _ in 0..n_words {
        let w = rng.below(world.lexicon.len());
        for &p in &world.lexicon[w] {
            for _ in 0..3 {
                push(p, rng);
            }
            push(0, rng);
        }
    }
    let t = rows.len() / labels;
    (rows, t)
}

fn main() {
    let b = Bench::default();
    let world = World::new();
    let mut rng = Xoshiro256::new(0xDEC);
    let corpus = text_corpus(20_000, 0xC0_0C, &world);
    let labels = quantasr::frontend::spec::N_LABELS;

    println!("== bench_decoder ==");
    let (lp, t) = posteriors(&world, 3, &mut rng);
    println!("utterance: {t} frames (~{:.1}s audio)\n", t as f64 * 0.02);

    for beam in [4usize, 8, 16, 24, 48] {
        let dec = Decoder::new(
            LexTrie::from_world(&world),
            NGramLm::small(&corpus, 200),
            NGramLm::large(&corpus, 200),
            DecoderConfig { beam, ..Default::default() },
        );
        let m = b.run_with_items(&format!("word beam search beam={beam}"), t as f64, || {
            dec.decode(&lp, labels)
        });
        println!(
            "  → {:.1}× realtime\n",
            (t as f64 * 0.02) / (m.mean_ns * 1e-9)
        );
    }

    b.run_with_items("phone prefix beam (8)", t as f64, || {
        ctc::prefix_beam(&lp, labels, 8)
    });
    b.run_with_items("greedy decode", t as f64, || ctc::greedy(&lp, labels));

    // LM scoring throughput.
    let lm = NGramLm::large(&corpus, 200);
    let hist = [3u32, 17];
    b.run_with_items("trigram LM log_prob", 1.0, || lm.log_prob(&hist, 42));

    // WER scoring.
    let mut a = vec![0u32; 30];
    let mut c = vec![0u32; 30];
    for v in a.iter_mut() {
        *v = rng.below(200) as u32;
    }
    for v in c.iter_mut() {
        *v = rng.below(200) as u32;
    }
    b.run_with_items("wer align 30×30", 900.0, || wer::align(&a, &c));

    println!("\nLM stats: small {} n-grams, large {} n-grams, ppl(held-out) small {:.1} large {:.1}",
        NGramLm::small(&corpus, 200).num_ngrams(),
        lm.num_ngrams(),
        NGramLm::small(&corpus, 200).perplexity(&text_corpus(500, 1, &world)),
        lm.perplexity(&text_corpus(500, 1, &world)),
    );
}
