//! Decoder benches: the decode kernel ladder (seed per-hypothesis HashMap
//! reference vs SoA beam lanes) at batch 1/8/32, word beam search cost vs
//! beam width, phone prefix beam, n-gram LM scoring throughput, WER
//! scoring.  (§4 decoding setup; the decoder shares the embedded
//! real-time budget with the AM.)
//!
//! Results are also written to `BENCH_decoder.json` so the perf
//! trajectory is recorded across PRs.

use std::fmt::Write as _;

use quantasr::decoder::lm::NGramLm;
use quantasr::decoder::trie::LexTrie;
use quantasr::decoder::{ctc, wer, DecodeKernel, Decoder, DecoderConfig};
use quantasr::sim::dataset::text_corpus;
use quantasr::sim::World;
use quantasr::util::bench::{Bench, Measurement};
use quantasr::util::rng::Xoshiro256;

/// Synthetic peaked posteriors for a random in-lexicon word sequence.
fn posteriors(world: &World, n_words: usize, rng: &mut Xoshiro256) -> (Vec<f32>, usize) {
    let labels = quantasr::frontend::spec::N_LABELS;
    let mut rows: Vec<f32> = Vec::new();
    let mut push = |id: u32, rng: &mut Xoshiro256| {
        let mut r = vec![0f32; labels];
        for v in r.iter_mut() {
            *v = rng.normal() as f32 * 0.3 - 6.0;
        }
        r[id as usize] = -0.05;
        rows.extend(r);
    };
    push(0, rng);
    for _ in 0..n_words {
        let w = rng.below(world.lexicon.len());
        for &p in &world.lexicon[w] {
            for _ in 0..3 {
                push(p, rng);
            }
            push(0, rng);
        }
    }
    let t = rows.len() / labels;
    (rows, t)
}

fn kernel_name(k: DecodeKernel) -> String {
    format!("{:?}", k).to_ascii_lowercase()
}

fn main() {
    let b = Bench::default();
    let world = World::new();
    let mut rng = Xoshiro256::new(0xDEC);
    let corpus = text_corpus(20_000, 0xC0_0C, &world);
    let labels = quantasr::frontend::spec::N_LABELS;

    println!("== bench_decoder ==");
    let (lp, t) = posteriors(&world, 3, &mut rng);
    println!("utterance: {t} frames (~{:.1}s audio)\n", t as f64 * 0.02);

    // Kernel ladder × batch: seed reference search vs the SoA beam-lane
    // rewrite (scalar and the best available SIMD rung), each over 1/8/32
    // utterances per call — batch>1 goes through `decode_batch_with_kernel`
    // so the shared-LmCache amortization is measured too.
    println!("== decode kernel ladder × batch ==");
    let dec = Decoder::new(
        LexTrie::from_world(&world),
        NGramLm::small(&corpus, 200),
        NGramLm::large(&corpus, 200),
        DecoderConfig { beam: 8, ..Default::default() },
    );
    let soa = DecodeKernel::Auto.resolve();
    let ladder: Vec<DecodeKernel> = if soa == DecodeKernel::Scalar {
        vec![DecodeKernel::Reference, DecodeKernel::Scalar]
    } else {
        vec![DecodeKernel::Reference, DecodeKernel::Scalar, soa]
    };
    // (kernel, batch, measurement) rows for the JSON ladder section.
    let mut ladder_rows: Vec<(String, usize, Measurement)> = Vec::new();
    for batch in [1usize, 8, 32] {
        let utts: Vec<(Vec<f32>, usize)> =
            (0..batch).map(|_| posteriors(&world, 3, &mut rng)).collect();
        let jobs: Vec<(&[f32], usize)> =
            utts.iter().map(|(rows, _)| (rows.as_slice(), labels)).collect();
        let total_frames: usize = utts.iter().map(|(_, t)| *t).sum();
        for &k in &ladder {
            let name = kernel_name(k);
            let m = b.run_with_items(
                &format!("decode {name} b{batch}"),
                total_frames as f64,
                || dec.decode_batch_with_kernel(&jobs, k),
            );
            ladder_rows.push((name, batch, m));
        }
        let reference = ladder_rows
            .iter()
            .find(|(n, bb, _)| n == "reference" && *bb == batch)
            .map(|(_, _, m)| m.mean_ns)
            .unwrap_or(0.0);
        let best = ladder_rows
            .iter()
            .filter(|(n, bb, _)| n != "reference" && *bb == batch)
            .map(|(_, _, m)| m.mean_ns)
            .fold(f64::INFINITY, f64::min);
        println!("  → b{batch}: SoA speedup {:.2}× vs reference\n", reference / best);
    }

    let mut recorded: Vec<Measurement> = Vec::new();
    for beam in [4usize, 8, 16, 24, 48] {
        let dec = Decoder::new(
            LexTrie::from_world(&world),
            NGramLm::small(&corpus, 200),
            NGramLm::large(&corpus, 200),
            DecoderConfig { beam, ..Default::default() },
        );
        let m = b.run_with_items(&format!("word beam search beam={beam}"), t as f64, || {
            dec.decode(&lp, labels)
        });
        println!(
            "  → {:.1}× realtime\n",
            (t as f64 * 0.02) / (m.mean_ns * 1e-9)
        );
        recorded.push(m);
    }

    recorded.push(b.run_with_items("phone prefix beam (8)", t as f64, || {
        ctc::prefix_beam(&lp, labels, 8)
    }));
    recorded.push(b.run_with_items("greedy decode", t as f64, || ctc::greedy(&lp, labels)));

    // LM scoring throughput.
    let lm = NGramLm::large(&corpus, 200);
    let hist = [3u32, 17];
    recorded.push(b.run_with_items("trigram LM log_prob", 1.0, || lm.log_prob(&hist, 42)));

    // WER scoring.
    let mut a = vec![0u32; 30];
    let mut c = vec![0u32; 30];
    for v in a.iter_mut() {
        *v = rng.below(200) as u32;
    }
    for v in c.iter_mut() {
        *v = rng.below(200) as u32;
    }
    recorded.push(b.run_with_items("wer align 30×30", 900.0, || wer::align(&a, &c)));

    println!("\nLM stats: small {} n-grams, large {} n-grams, ppl(held-out) small {:.1} large {:.1}",
        NGramLm::small(&corpus, 200).num_ngrams(),
        lm.num_ngrams(),
        NGramLm::small(&corpus, 200).perplexity(&text_corpus(500, 1, &world)),
        lm.perplexity(&text_corpus(500, 1, &world)),
    );

    // Emit BENCH_decoder.json so the perf trajectory is recorded across PRs.
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"decoder\",\n  \"ladder\": [\n");
    for (i, (kernel, batch, m)) in ladder_rows.iter().enumerate() {
        let comma = if i + 1 < ladder_rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"kernel\": \"{kernel}\", \"batch\": {batch}, \"mean_ns\": {:.1}, \
             \"p50_ns\": {:.1}, \"p99_ns\": {:.1}, \"frames_per_s\": {:.1}}}{comma}",
            m.mean_ns,
            m.p50_ns,
            m.p99_ns,
            m.throughput().unwrap_or(0.0),
        );
    }
    json.push_str("  ],\n  \"speedup\": [\n");
    let batches = [1usize, 8, 32];
    for (i, &batch) in batches.iter().enumerate() {
        let reference = ladder_rows
            .iter()
            .find(|(n, bb, _)| n == "reference" && *bb == batch)
            .map(|(_, _, m)| m.mean_ns)
            .unwrap_or(0.0);
        let best = ladder_rows
            .iter()
            .filter(|(n, bb, _)| n != "reference" && *bb == batch)
            .map(|(_, _, m)| m.mean_ns)
            .fold(f64::INFINITY, f64::min);
        let comma = if i + 1 < batches.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"batch\": {batch}, \"soa_vs_reference\": {:.2}}}{comma}",
            reference / best.max(1e-9)
        );
    }
    json.push_str("  ],\n  \"results\": [\n");
    for (i, m) in recorded.iter().enumerate() {
        let comma = if i + 1 < recorded.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"name\": \"{}\", \"mean_ns\": {:.1}, \"p50_ns\": {:.1}, \"p99_ns\": {:.1}, \"iters\": {}}}{comma}",
            m.name, m.mean_ns, m.p50_ns, m.p99_ns, m.iters
        );
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_decoder.json", &json) {
        Ok(()) => println!("\nwrote BENCH_decoder.json"),
        Err(e) => eprintln!("\ncould not write BENCH_decoder.json: {e}"),
    }
}
