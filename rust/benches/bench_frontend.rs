//! Frontend throughput: the frontend kernel ladder (seed complex-FFT +
//! dense mel reference vs real-input FFT + fused sparse mel+log) streaming
//! at 1/8/32 parallel streams, plus the FFT kernels in isolation.  (The
//! paper's embedded budget: the frontend must be a negligible slice of
//! the real-time budget.)
//!
//! Results are also written to `BENCH_frontend.json` so the perf
//! trajectory is recorded across PRs.

use std::fmt::Write as _;

use quantasr::frontend::fft::{Complex, FftPlan, RealFftPlan};
use quantasr::frontend::{
    features, push_batch, spec, BatchStream, Frontend, FrontendKernel,
};
use quantasr::util::bench::{Bench, Measurement};
use quantasr::util::rng::Xoshiro256;

fn kernel_name(k: FrontendKernel) -> String {
    format!("{:?}", k).to_ascii_lowercase()
}

fn tone_wave(secs: f64, rng: &mut Xoshiro256) -> Vec<f32> {
    let n = (secs * spec::SAMPLE_RATE as f64) as usize;
    let mut wave = vec![0f32; n];
    for (i, v) in wave.iter_mut().enumerate() {
        let t = i as f64 / spec::SAMPLE_RATE as f64;
        *v = (2.0 * std::f64::consts::PI * 700.0 * t).sin() as f32 * 0.3
            + rng.normal() as f32 * 0.02;
    }
    wave
}

fn main() {
    let b = Bench::default();
    let mut rng = Xoshiro256::new(0xFE);

    println!("== bench_frontend ==");
    let secs = 4.0;
    let wave = tone_wave(secs, &mut rng);
    let n = wave.len();

    let m = b.run_with_items(&format!("batch features {secs}s audio"), n as f64, || {
        features(&wave)
    });
    println!("  → {:.0}× realtime\n", secs / (m.mean_ns * 1e-9));
    let mut recorded: Vec<Measurement> = vec![m];

    // Kernel ladder × streams: the seed complex-FFT + dense mel path vs
    // the fused real-FFT rungs, streaming 80 ms chunks.  streams>1 goes
    // through `push_batch` so the worker-pool fan-out is measured too.
    println!("== frontend kernel ladder × streams ==");
    let fused = FrontendKernel::Auto.resolve();
    let ladder: Vec<FrontendKernel> = if fused == FrontendKernel::Scalar {
        vec![FrontendKernel::Reference, FrontendKernel::Scalar]
    } else {
        vec![FrontendKernel::Reference, FrontendKernel::Scalar, fused]
    };
    let mut ladder_rows: Vec<(String, usize, Measurement)> = Vec::new();
    for streams in [1usize, 8, 32] {
        let waves: Vec<Vec<f32>> = (0..streams).map(|_| tone_wave(secs, &mut rng)).collect();
        for &k in &ladder {
            let name = kernel_name(k);
            let mut fes: Vec<Frontend> =
                (0..streams).map(|_| Frontend::with_kernel(k)).collect();
            let mut outs: Vec<Vec<f32>> = vec![Vec::new(); streams];
            let m = b.run_with_items(
                &format!("streaming {name} s{streams}"),
                (n * streams) as f64,
                || {
                    let mut emitted = 0usize;
                    for (fe, out) in fes.iter_mut().zip(outs.iter_mut()) {
                        fe.reset();
                        out.clear();
                    }
                    // 640-sample (80 ms) chunks, matching the seed
                    // streaming bench so rows stay comparable across PRs.
                    for chunk_start in (0..n).step_by(640) {
                        let end = (chunk_start + 640).min(n);
                        let mut batch: Vec<BatchStream> = fes
                            .iter_mut()
                            .zip(outs.iter_mut())
                            .zip(&waves)
                            .map(|((fe, out), wave)| BatchStream {
                                fe,
                                pcm: &wave[chunk_start..end],
                                out,
                                emitted: 0,
                            })
                            .collect();
                        push_batch(&mut batch);
                        emitted += batch.iter().map(|s| s.emitted).sum::<usize>();
                    }
                    emitted
                },
            );
            ladder_rows.push((name, streams, m));
        }
        let reference = ladder_rows
            .iter()
            .find(|(nm, s, _)| nm == "reference" && *s == streams)
            .map(|(_, _, m)| m.mean_ns)
            .unwrap_or(0.0);
        let best = ladder_rows
            .iter()
            .filter(|(nm, s, _)| nm != "reference" && *s == streams)
            .map(|(_, _, m)| m.mean_ns)
            .fold(f64::INFINITY, f64::min);
        println!("  → s{streams}: fused speedup {:.2}× vs reference\n", reference / best);
    }

    // FFT kernels in isolation: complex 256-point plan vs the real-input
    // plan that does half the butterfly work.
    let plan = FftPlan::new(spec::FFT_SIZE);
    let rplan = RealFftPlan::new(spec::FFT_SIZE);
    let mut scratch = vec![Complex::default(); spec::FFT_SIZE];
    let mut rscratch = vec![Complex::default(); spec::FFT_SIZE / 2];
    let mut power = vec![0f32; spec::FFT_SIZE / 2 + 1];
    let frame: Vec<f32> = wave[..spec::FRAME_LEN].to_vec();
    let m_c = b.run_with_items("fft256 power spectrum (complex)", spec::FFT_SIZE as f64, || {
        plan.power_spectrum(&frame, &mut scratch, &mut power)
    });
    let m_r = b.run_with_items("fft256 power spectrum (real)", spec::FFT_SIZE as f64, || {
        rplan.power_spectrum(&frame, &mut rscratch, &mut power)
    });
    println!("  → real-input FFT speedup {:.2}×\n", m_c.mean_ns / m_r.mean_ns.max(1e-9));
    recorded.push(m_c);
    recorded.push(m_r);

    // Emit BENCH_frontend.json so the perf trajectory is recorded across PRs.
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"frontend\",\n  \"ladder\": [\n");
    for (i, (kernel, streams, m)) in ladder_rows.iter().enumerate() {
        let comma = if i + 1 < ladder_rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"kernel\": \"{kernel}\", \"streams\": {streams}, \"mean_ns\": {:.1}, \
             \"p50_ns\": {:.1}, \"p99_ns\": {:.1}, \"samples_per_s\": {:.1}}}{comma}",
            m.mean_ns,
            m.p50_ns,
            m.p99_ns,
            m.throughput().unwrap_or(0.0),
        );
    }
    json.push_str("  ],\n  \"speedup\": [\n");
    let stream_counts = [1usize, 8, 32];
    for (i, &streams) in stream_counts.iter().enumerate() {
        let reference = ladder_rows
            .iter()
            .find(|(nm, s, _)| nm == "reference" && *s == streams)
            .map(|(_, _, m)| m.mean_ns)
            .unwrap_or(0.0);
        let best = ladder_rows
            .iter()
            .filter(|(nm, s, _)| nm != "reference" && *s == streams)
            .map(|(_, _, m)| m.mean_ns)
            .fold(f64::INFINITY, f64::min);
        let comma = if i + 1 < stream_counts.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"streams\": {streams}, \"fused_vs_reference\": {:.2}}}{comma}",
            reference / best.max(1e-9)
        );
    }
    let _ = writeln!(
        json,
        "  ],\n  \"real_fft_speedup\": {:.2},\n  \"results\": [",
        m_c.mean_ns / m_r.mean_ns.max(1e-9)
    );
    for (i, m) in recorded.iter().enumerate() {
        let comma = if i + 1 < recorded.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"name\": \"{}\", \"mean_ns\": {:.1}, \"p50_ns\": {:.1}, \"p99_ns\": {:.1}, \"iters\": {}}}{comma}",
            m.name, m.mean_ns, m.p50_ns, m.p99_ns, m.iters
        );
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_frontend.json", &json) {
        Ok(()) => println!("\nwrote BENCH_frontend.json"),
        Err(e) => eprintln!("\ncould not write BENCH_frontend.json: {e}"),
    }
}
