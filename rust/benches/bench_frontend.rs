//! Frontend throughput: PCM → features, batch and streaming, plus the FFT
//! kernel in isolation.  (The paper's embedded budget: the frontend must be
//! a negligible slice of the real-time budget.)

use quantasr::frontend::fft::{Complex, FftPlan};
use quantasr::frontend::{features, spec, Frontend};
use quantasr::util::bench::Bench;
use quantasr::util::rng::Xoshiro256;

fn main() {
    let b = Bench::default();
    let mut rng = Xoshiro256::new(0xFE);

    println!("== bench_frontend ==");
    let secs = 4.0;
    let n = (secs * spec::SAMPLE_RATE as f64) as usize;
    let mut wave = vec![0f32; n];
    for (i, v) in wave.iter_mut().enumerate() {
        let t = i as f64 / spec::SAMPLE_RATE as f64;
        *v = (2.0 * std::f64::consts::PI * 700.0 * t).sin() as f32 * 0.3
            + rng.normal() as f32 * 0.02;
    }

    let m = b.run_with_items(&format!("batch features {secs}s audio"), n as f64, || {
        features(&wave)
    });
    println!(
        "  → {:.0}× realtime\n",
        secs / (m.mean_ns * 1e-9)
    );

    let mut fe = Frontend::new();
    let mut out = Vec::new();
    b.run_with_items("streaming push 80ms chunks", n as f64, || {
        fe.reset();
        out.clear();
        for chunk in wave.chunks(640) {
            fe.push(chunk, &mut out);
        }
        out.len()
    });

    let plan = FftPlan::new(spec::FFT_SIZE);
    let mut scratch = vec![Complex::default(); spec::FFT_SIZE];
    let mut power = vec![0f32; spec::FFT_SIZE / 2 + 1];
    let frame: Vec<f32> = wave[..spec::FRAME_LEN].to_vec();
    b.run_with_items("fft256 power spectrum", spec::FFT_SIZE as f64, || {
        plan.power_spectrum(&frame, &mut scratch, &mut power)
    });
}
