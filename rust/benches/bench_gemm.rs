//! E1 — the paper's §3.1 efficiency claim at the kernel level: integer
//! (u8·u8→i32) GEMM vs f32 GEMM, across the matrix shapes of the Table-1
//! model family plus square sizes, and across the kernel ladder
//! (scalar → unrolled → AVX2).
//!
//! Reported as MACs/s; the "speedup" lines are what EXPERIMENTS.md §E1
//! quotes.  Run with `cargo bench --bench bench_gemm`.

use quantasr::quant::gemm::{fgemm, qgemm, FMatrix, Kernel, QScratch};
use quantasr::quant::{Granularity, QMatrix};
use quantasr::util::bench::Bench;
use quantasr::util::rng::Xoshiro256;

fn randv(n: usize, rng: &mut Xoshiro256) -> Vec<f32> {
    let mut v = vec![0f32; n];
    rng.fill_normal(&mut v);
    v
}

fn main() {
    let b = Bench::default();
    let mut rng = Xoshiro256::new(0xE1);
    println!("== bench_gemm: integer vs float GEMM (E1) ==");
    println!("host AVX2: {}", std::arch::is_x86_feature_detected!("avx2"));

    // (batch, in, out): LSTM gate matmuls of the Table-1 grid + squares.
    let shapes = [
        (1usize, 64usize, 120usize),   // 4x30 wx (stream)
        (1, 50, 200),                  // 5x50 wh
        (8, 64, 200),                  // batched serving
        (8, 50, 200),
        (1, 256, 256),
        (8, 256, 256),
        (8, 512, 512),
        (1, 1024, 1024),
    ];
    for (batch, k, n) in shapes {
        let x = randv(batch * k, &mut rng);
        let wf = randv(k * n, &mut rng);
        let bias = randv(n, &mut rng);
        let qm = QMatrix::from_f32_math_layout(&wf, k, n, Granularity::PerMatrix);
        let fm = FMatrix::from_math_layout(&wf, k, n);
        let macs = (batch * k * n) as f64;
        let mut y = vec![0f32; batch * n];
        let mut scratch = QScratch::default();

        let m_f32 = b.run_with_items(
            &format!("f32 gemm        {batch}x{k}x{n}"),
            macs,
            || fgemm(&x, batch, &fm, Some(&bias), &mut y, false),
        );
        let m_scalar = b.run_with_items(
            &format!("u8 gemm scalar  {batch}x{k}x{n}"),
            macs,
            || qgemm(&x, batch, &qm, Some(&bias), &mut y, &mut scratch, Kernel::Scalar, false),
        );
        let m_unroll = b.run_with_items(
            &format!("u8 gemm unroll  {batch}x{k}x{n}"),
            macs,
            || qgemm(&x, batch, &qm, Some(&bias), &mut y, &mut scratch, Kernel::Unrolled, false),
        );
        let m_best = b.run_with_items(
            &format!("u8 gemm auto    {batch}x{k}x{n}"),
            macs,
            || qgemm(&x, batch, &qm, Some(&bias), &mut y, &mut scratch, Kernel::Auto, false),
        );
        println!(
            "  → int8 speedup vs f32: scalar {:.2}×  unrolled {:.2}×  auto {:.2}×\n",
            m_f32.mean_ns / m_scalar.mean_ns,
            m_f32.mean_ns / m_unroll.mean_ns,
            m_f32.mean_ns / m_best.mean_ns,
        );
    }

    // Memory footprint comparison (the 4× claim).
    let wf = randv(512 * 512, &mut rng);
    let qm = QMatrix::from_f32_math_layout(&wf, 512, 512, Granularity::PerMatrix);
    let fm = FMatrix::from_math_layout(&wf, 512, 512);
    println!(
        "storage 512×512: f32 {} KB vs u8 {} KB ({:.2}× smaller)",
        fm.storage_bytes() / 1024,
        qm.storage_bytes() / 1024,
        fm.storage_bytes() as f64 / qm.storage_bytes() as f64
    );
}
