//! E1 + the packed-panel perf gate: the integer GEMM **kernel ladder**
//! (scalar → unrolled → AVX2 row-dot → packed panels → packed VNNI) across
//! representative LSTM shapes at batch 1/8/32, against the f32 baseline.
//!
//! The acceptance bar for the packed-panel work is recorded here: on the
//! representative 512×2048 shape at batch 8, the packed path (with panel
//! parallelism, as dispatched in production) must beat the old `Avx2`
//! row-dot rung ≥ 2×.  Results are written to `BENCH_gemm.json` (CI
//! uploads it as an artifact) so the perf trajectory persists across PRs.
//!
//! Env knobs: `QUANTASR_GEMM_THREADS=1` pins the packed path serial (to
//! isolate microkernel gains from parallel gains); `QUANTASR_KERNEL`
//! forces the Auto rung.

use std::fmt::Write as _;

use quantasr::quant::gemm::{fgemm, qgemm, FMatrix, Kernel, QScratch};
use quantasr::quant::{Granularity, QMatrix, QuantScheme};
use quantasr::util::bench::{Bench, Measurement};
use quantasr::util::pool::WorkerPool;
use quantasr::util::rng::Xoshiro256;

fn randv(n: usize, rng: &mut Xoshiro256) -> Vec<f32> {
    let mut v = vec![0f32; n];
    rng.fill_normal(&mut v);
    v
}

/// One ladder row destined for BENCH_gemm.json.
struct Row {
    batch: usize,
    k: usize,
    n: usize,
    kernel: String,
    m: Measurement,
    macs: f64,
}

fn find_ns(rows: &[Row], batch: usize, k: usize, n: usize, kernel: &str) -> Option<f64> {
    rows.iter()
        .find(|r| r.batch == batch && r.k == k && r.n == n && r.kernel == kernel)
        .map(|r| r.m.mean_ns)
}

fn main() {
    let b = Bench::default();
    let mut rng = Xoshiro256::new(0xE1);
    let mut rows: Vec<Row> = Vec::new();
    println!("== bench_gemm: integer GEMM kernel ladder vs f32 (E1 + packed panels) ==");
    let avx2 = {
        #[cfg(target_arch = "x86_64")]
        {
            quantasr::quant::gemm::avx2_available()
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("host: avx2={avx2} vnni_feature={} cpus={threads}", cfg!(feature = "vnni"));

    // The forced-kernel ladder this host can run (f32 benched separately).
    let mut ladder: Vec<(&str, Kernel)> = vec![
        ("scalar", Kernel::Scalar),
        ("unrolled", Kernel::Unrolled),
        ("packed-scalar", Kernel::PackedScalar),
    ];
    #[cfg(target_arch = "x86_64")]
    if avx2 {
        ladder.push(("avx2-rowdot", Kernel::Avx2));
        ladder.push(("packed-avx2", Kernel::PackedAvx2));
    }
    #[cfg(all(target_arch = "x86_64", feature = "vnni"))]
    if quantasr::quant::gemm::vnni_available() {
        ladder.push(("packed-vnni", Kernel::PackedVnni));
    }
    ladder.push(("auto", Kernel::Auto));

    // Representative LSTM shapes (k = in, n = out):
    //   512×2048 — the acceptance shape (cell 512 gate block);
    //   200×2000 — paper-scale 5×500 P=200 wx/wh gate matmul;
    //   500×200  — the recurrent projection.
    let shapes = [(512usize, 2048usize), (200, 2000), (500, 200)];
    let batches = [1usize, 8, 32];
    for (k, n) in shapes {
        for batch in batches {
            let x = randv(batch * k, &mut rng);
            let wf = randv(k * n, &mut rng);
            let bias = randv(n, &mut rng);
            let qm = QMatrix::from_f32_math_layout(&wf, k, n, Granularity::PerMatrix);
            let fm = FMatrix::from_math_layout(&wf, k, n);
            let macs = (batch * k * n) as f64;
            let mut y = vec![0f32; batch * n];
            let mut scratch = QScratch::default();

            let m_f32 = b.run_with_items(
                &format!("f32 gemm           {batch}x{k}x{n}"),
                macs,
                || fgemm(&x, batch, &fm, Some(&bias), &mut y, false),
            );
            rows.push(Row { batch, k, n, kernel: "f32".into(), m: m_f32, macs });
            for &(name, kern) in &ladder {
                let m = b.run_with_items(
                    &format!("u8 {name:<15} {batch}x{k}x{n}"),
                    macs,
                    || qgemm(&x, batch, &qm, Some(&bias), &mut y, &mut scratch, kern, false),
                );
                rows.push(Row { batch, k, n, kernel: name.into(), m, macs });
            }
            let f32_ns = find_ns(&rows, batch, k, n, "f32");
            let avx2_ns = find_ns(&rows, batch, k, n, "avx2-rowdot");
            let auto_ns = find_ns(&rows, batch, k, n, "auto");
            if let (Some(f), Some(a)) = (f32_ns, auto_ns) {
                let vs_avx2 = avx2_ns
                    .map(|r| format!("  vs avx2-rowdot {:.2}×", r / a))
                    .unwrap_or_default();
                println!("  → auto vs f32 {:.2}×{vs_avx2}\n", f / a);
            }
        }
    }

    // Requantization-scheme axis on the acceptance shape: the per-channel
    // finish must not tax the u8 path, and the int4 nibble kernels must
    // convert their halved panel footprint into batch-32 throughput (the
    // i4-vs-u8 acceptance ratio recorded in BENCH_gemm.json).
    println!("== scheme axis (auto rung, 512×2048) ==");
    let (k, n) = (512usize, 2048usize);
    let wf = randv(k * n, &mut rng);
    let bias = randv(n, &mut rng);
    let schemes = [
        ("isq-per-matrix-u8", QuantScheme::PerMatrixU8),
        ("isq-per-channel-u8", QuantScheme::PerChannelU8),
        ("isq-per-channel-i4", QuantScheme::PerChannelI4),
    ];
    for batch in batches {
        let x = randv(batch * k, &mut rng);
        let macs = (batch * k * n) as f64;
        let mut y = vec![0f32; batch * n];
        let mut scratch = QScratch::default();
        for &(name, scheme) in &schemes {
            let qm = QMatrix::from_f32_math_layout_scheme(&wf, k, n, scheme);
            let m = b.run_with_items(
                &format!("{name:<18} {batch}x{k}x{n}"),
                macs,
                || qgemm(&x, batch, &qm, Some(&bias), &mut y, &mut scratch, Kernel::Auto, false),
            );
            rows.push(Row { batch, k, n, kernel: name.into(), m, macs });
        }
        if let (Some(u8ns), Some(i4ns)) = (
            find_ns(&rows, batch, k, n, "isq-per-channel-u8"),
            find_ns(&rows, batch, k, n, "isq-per-channel-i4"),
        ) {
            println!("  → i4 vs per-channel-u8 {:.2}× (batch {batch})\n", u8ns / i4ns);
        }
    }

    // Worker-pool dispatch overhead: a no-op job through the persistent
    // pool measures the fixed cost a parallel GEMM pays over a serial one
    // (the number that justified dropping the 2M-MAC spawn threshold to
    // 256K).  Batch-1 latency regressions from the pool would show up in
    // the b1 ladder rows above; this isolates the mechanism.
    let pool = WorkerPool::global();
    let pool_threads = pool.workers() + 1;
    // With zero workers every run() is inline — there is no dispatch to
    // measure, so record null rather than a meaningless number.
    let m_pool = if pool.workers() > 0 {
        Some(b.run_with_items(
            &format!("pool dispatch ({pool_threads} executors, no-op job)"),
            1.0,
            || pool.run(pool_threads, pool_threads, &|_| {}),
        ))
    } else {
        println!("pool dispatch: 0 workers on this host (inline execution), skipping");
        None
    };
    println!();

    // Memory footprint comparison (the 4× claim) + the packed mirror cost.
    let wf = randv(512 * 512, &mut rng);
    let qm = QMatrix::from_f32_math_layout(&wf, 512, 512, Granularity::PerMatrix);
    let fm = FMatrix::from_math_layout(&wf, 512, 512);
    println!(
        "storage 512×512: f32 {} KB vs u8 {} KB ({:.2}× smaller); packed mirror +{} KB",
        fm.storage_bytes() / 1024,
        qm.storage_bytes() / 1024,
        fm.storage_bytes() as f64 / qm.storage_bytes() as f64,
        qm.packed_bytes() / 1024,
    );

    // Emit BENCH_gemm.json: the raw ladder plus the packed-vs-rowdot and
    // int8-vs-f32 speedups per shape (the perf-trajectory artifact).
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"gemm\",\n");
    let _ = writeln!(
        json,
        "  \"host\": {{\"avx2\": {avx2}, \"vnni_feature\": {}, \"cpus\": {threads}}},",
        cfg!(feature = "vnni")
    );
    let _ = writeln!(
        json,
        "  \"pool\": {{\"workers\": {}, \"dispatch_ns\": {}}},",
        pool.workers(),
        m_pool.as_ref().map_or("null".into(), |m| format!("{:.1}", m.mean_ns))
    );
    json.push_str("  \"ladder\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"batch\": {}, \"k\": {}, \"n\": {}, \"kernel\": \"{}\", \
             \"mean_ns\": {:.1}, \"p50_ns\": {:.1}, \"p99_ns\": {:.1}, \
             \"gmacs_per_s\": {:.3}}}{comma}",
            r.batch,
            r.k,
            r.n,
            r.kernel,
            r.m.mean_ns,
            r.m.p50_ns,
            r.m.p99_ns,
            r.macs / r.m.mean_ns, // MACs per ns == GMACs per s
        );
    }
    json.push_str("  ],\n  \"speedups\": [\n");
    let mut lines: Vec<String> = Vec::new();
    for (k, n) in shapes {
        for batch in batches {
            let (Some(f32_ns), Some(auto_ns)) = (
                find_ns(&rows, batch, k, n, "f32"),
                find_ns(&rows, batch, k, n, "auto"),
            ) else {
                continue;
            };
            let packed_vs_rowdot = match (
                find_ns(&rows, batch, k, n, "avx2-rowdot"),
                find_ns(&rows, batch, k, n, "packed-avx2"),
            ) {
                (Some(r), Some(p)) => format!("{:.3}", r / p),
                _ => "null".into(),
            };
            let auto_vs_rowdot = match find_ns(&rows, batch, k, n, "avx2-rowdot") {
                Some(r) => format!("{:.3}", r / auto_ns),
                None => "null".into(),
            };
            lines.push(format!(
                "    {{\"batch\": {batch}, \"k\": {k}, \"n\": {n}, \
                 \"auto_vs_f32\": {:.3}, \"packed_avx2_vs_avx2_rowdot\": {packed_vs_rowdot}, \
                 \"auto_vs_avx2_rowdot\": {auto_vs_rowdot}}}",
                f32_ns / auto_ns
            ));
        }
    }
    json.push_str(&lines.join(",\n"));
    json.push_str("\n  ],\n  \"isq\": [\n");
    let mut lines: Vec<String> = Vec::new();
    for batch in batches {
        let (Some(u8ns), Some(i4ns), Some(pmns)) = (
            find_ns(&rows, batch, 512, 2048, "isq-per-channel-u8"),
            find_ns(&rows, batch, 512, 2048, "isq-per-channel-i4"),
            find_ns(&rows, batch, 512, 2048, "isq-per-matrix-u8"),
        ) else {
            continue;
        };
        lines.push(format!(
            "    {{\"batch\": {batch}, \"k\": 512, \"n\": 2048, \
             \"i4_vs_pc_u8\": {:.3}, \"pc_u8_vs_pm_u8\": {:.3}}}",
            u8ns / i4ns,
            pmns / u8ns
        ));
    }
    json.push_str(&lines.join(",\n"));
    json.push_str("\n  ]\n}\n");
    match std::fs::write("BENCH_gemm.json", &json) {
        Ok(()) => println!("\nwrote BENCH_gemm.json"),
        Err(e) => eprintln!("\ncould not write BENCH_gemm.json: {e}"),
    }
}
