//! E1/E4 — end-to-end acoustic-model benches:
//! (a) full-model single-stream step latency + real-time factor, float vs
//!     int8, across the Table-1 architecture grid ("the cost of inference",
//!     §3.1) — uses trained artifacts when present, random weights else;
//! (b) the serving engine's batched throughput vs max_batch (the L3
//!     batching ablation);
//! (c) per-tick state movement: the legacy gather/scatter batch assembly
//!     vs in-place `BatchArena` lane stepping — the copies the
//!     lane-resident engine eliminated;
//! (h) the overload-control plane under deliberate abuse: a Bulk flood
//!     plus a scripted `overload_tick` fault window drive the brownout
//!     controller through shed → reject → recover while interactive
//!     finalize latency is sampled before/during/after, and a canaried
//!     zero-downtime swap is timed against a constant admission knocker;
//! (i) the flight recorder's cost: the 32-stream saturated workload run
//!     with tracing disabled then enabled (`obs::set_enabled`), per-tick
//!     latency and throughput side by side — the always-on contract is
//!     tracing-on tick p99 within a few percent of off.
//!
//! Results are also written to `BENCH_engine.json` (and the tracing
//! comparison to `BENCH_trace.json`) so the perf trajectory is recorded
//! across PRs.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use quantasr::coordinator::batcher::BatchPolicy;
use quantasr::coordinator::{Engine, EngineConfig};
use quantasr::decoder::DecoderConfig;
use quantasr::eval::build_decoder;
use quantasr::frontend::spec;
use quantasr::io::model_fmt::{ModelHeader, QamFile, Tensor};
use quantasr::nn::{AcousticModel, ExecMode};
use quantasr::sched::{
    ModelParams, ModelRegistry, Priority, QuantumPolicy, RejectReason, StreamOptions,
};
use quantasr::sim::World;
use quantasr::util::bench::{fmt_ns, Bench, Measurement};
use quantasr::util::fault::FaultPlan;
use quantasr::util::rng::Xoshiro256;

fn random_qam(layers: usize, cells: usize, proj: Option<usize>) -> QamFile {
    let input_dim = spec::FEAT_DIM;
    let labels = spec::N_LABELS;
    let rec = proj.unwrap_or(cells);
    let mut rng = Xoshiro256::new(0xE2E);
    let mut tensors = BTreeMap::new();
    let mut mk = |name: String, i: usize, o: usize, rng: &mut Xoshiro256| {
        let mut data = vec![0f32; i * o];
        rng.fill_normal(&mut data);
        for v in data.iter_mut() {
            *v *= (1.0 / i as f32).sqrt();
        }
        (name, Tensor::F32 { shape: vec![i, o], data })
    };
    for l in 0..layers {
        let ind = if l == 0 { input_dim } else { rec };
        let (nm, t) = mk(format!("l{l}.wx"), ind, 4 * cells, &mut rng);
        tensors.insert(nm, t);
        let (nm, t) = mk(format!("l{l}.wh"), rec, 4 * cells, &mut rng);
        tensors.insert(nm, t);
        tensors.insert(
            format!("l{l}.b"),
            Tensor::F32 { shape: vec![4 * cells], data: vec![0.0; 4 * cells] },
        );
        if let Some(p) = proj {
            let (nm, t) = mk(format!("l{l}.wp"), cells, p, &mut rng);
            tensors.insert(nm, t);
        }
    }
    let (nm, t) = mk("out.w".into(), rec, labels, &mut rng);
    tensors.insert(nm, t);
    tensors.insert("out.b".into(), Tensor::F32 { shape: vec![labels], data: vec![0.0; labels] });
    QamFile {
        header: ModelHeader {
            name: format!("{layers}x{cells}{}", proj.map(|p| format!("p{p}")).unwrap_or_default()),
            num_layers: layers,
            cell_dim: cells,
            proj_dim: proj,
            input_dim,
            num_labels: labels,
            quantized: false,
            quantize_output: false,
            param_count: 0,
        },
        tensors,
    }
}

fn main() {
    let b = Bench::default();
    let mut rng = Xoshiro256::new(7);
    let mut recorded: Vec<Measurement> = Vec::new();
    let mut throughput_rows: Vec<(usize, f64, f64)> = Vec::new();
    println!("== bench_e2e: full acoustic model, float vs int8 ==");
    println!("(frame = 20 ms of audio; RTF = compute time / audio time)\n");

    // The Table-1 grid + the paper-scale 5×500 P=200 for reference.
    let grid: &[(usize, usize, Option<usize>)] = &[
        (4, 30, None),
        (5, 50, None),
        (5, 50, Some(20)),
        (5, 500, Some(200)), // paper-scale width
    ];
    for &(layers, cells, proj) in grid {
        let qam = random_qam(layers, cells, proj);
        let mf = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
        let mq = AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap();
        let mut x = vec![0f32; spec::FEAT_DIM];
        rng.fill_normal(&mut x);
        let mut st_f = mf.new_state(1);
        let mut st_q = mq.new_state(1);
        let mut out = vec![0f32; mf.num_labels()];
        let name = qam.header.name.clone();
        let m_f = b.run_with_items(&format!("model f32  {name} b1"), 1.0, || {
            mf.step(&x, &mut st_f, &mut out)
        });
        let m_q = b.run_with_items(&format!("model int8 {name} b1"), 1.0, || {
            mq.step(&x, &mut st_q, &mut out)
        });
        let frame_s = spec::FRAME_SECONDS;
        println!(
            "  → int8 speedup {:.2}×;  RTF f32 {:.4}  int8 {:.4};  storage {}KB → {}KB\n",
            m_f.mean_ns / m_q.mean_ns,
            m_f.mean_ns * 1e-9 / frame_s,
            m_q.mean_ns * 1e-9 / frame_s,
            mf.storage_bytes() / 1024,
            mq.storage_bytes() / 1024,
        );
        recorded.push(m_f);
        recorded.push(m_q);
    }

    // (c) per-tick state movement: legacy gather/scatter vs BatchArena.
    // The seed engine assembled every batch by copying each stream's
    // recurrent state into a contiguous batch ModelState and copying it
    // back after the step; the lane-resident arena steps in place.
    println!("== per-tick state movement: gather/scatter vs BatchArena (batch 8) ==");
    {
        let nb = 8usize;
        let qam = random_qam(3, 48, Some(24));
        let model = AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap();
        let d = spec::FEAT_DIM;
        let labels = model.num_labels();
        let mut x = vec![0f32; nb * d];
        rng.fill_normal(&mut x);
        let mut out = vec![0f32; nb * labels];

        // Legacy tick: gather states → batched step → scatter states.
        let mut stream_states: Vec<_> = (0..nb).map(|_| model.new_state(1)).collect();
        let mut batch_state = model.new_state(nb);
        let m_legacy =
            b.run_with_items("tick legacy gather+step+scatter b8", nb as f64, || {
                for (i, st) in stream_states.iter().enumerate() {
                    batch_state.copy_stream_from(&model, i, st, 0);
                }
                model.step(&x, &mut batch_state, &mut out);
                for (i, st) in stream_states.iter_mut().enumerate() {
                    st.copy_stream_from(&model, 0, &batch_state, i);
                }
            });
        // The gather/scatter copies alone (the overhead the arena removes).
        let m_gs = b.run_with_items("tick gather/scatter copies only b8", nb as f64, || {
            for (i, st) in stream_states.iter().enumerate() {
                batch_state.copy_stream_from(&model, i, st, 0);
            }
            for (i, st) in stream_states.iter_mut().enumerate() {
                st.copy_stream_from(&model, 0, &batch_state, i);
            }
        });
        // Arena tick: step active lanes in place — no state movement.
        let mut arena = model.new_arena(nb);
        let lanes: Vec<usize> = (0..nb).collect();
        let m_arena = b.run_with_items("tick BatchArena in-place b8", nb as f64, || {
            model.arena_step(&mut arena, &lanes, &x, &mut out)
        });
        println!(
            "  → gather/scatter cost {} per tick ({:.1}% of the legacy tick) — \
             eliminated; arena tick speedup {:.2}× vs legacy\n",
            fmt_ns(m_gs.mean_ns),
            100.0 * m_gs.mean_ns / m_legacy.mean_ns.max(1e-9),
            m_legacy.mean_ns / m_arena.mean_ns.max(1e-9),
        );
        recorded.push(m_legacy);
        recorded.push(m_gs);
        recorded.push(m_arena);
    }

    // (b) serving engine: throughput vs max_batch — the lane-masked GEMM
    // scaling curve (ROADMAP "Bigger batches"): lanes are O(max_batch)
    // pre-allocated memory and the packed-panel GEMM computes every active
    // lane per panel pass, so this sweep (now through the raised default
    // of 32) records how far weight-streaming amortization carries.
    println!("== serving engine: batched frames/s vs max_batch (lane scaling curve) ==");
    let qam = random_qam(3, 48, Some(24));
    let world = World::new();
    let decoder = Arc::new(build_decoder(&world, DecoderConfig { beam: 8, ..Default::default() }));
    for max_batch in [1usize, 2, 4, 8, 16, 32] {
        let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
        let cfg = EngineConfig {
            policy: BatchPolicy {
                max_batch,
                deadline: std::time::Duration::from_millis(2),
            },
            decode_workers: 2,
            max_pending_frames: 128,
            ..EngineConfig::default()
        };
        let engine = Arc::new(Engine::start(model, decoder.clone(), cfg));
        let n_streams = 32;
        let frames_per_stream = 100;
        let mut frame = vec![0f32; spec::FEAT_DIM * frames_per_stream];
        rng.fill_normal(&mut frame);
        let t0 = std::time::Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..n_streams {
                let engine = engine.clone();
                let frame = frame.clone();
                scope.spawn(move || {
                    let (id, rx) = engine.open_stream();
                    engine.push_frames(id, &frame).unwrap();
                    engine.finish_stream(id).unwrap();
                    let _ = rx.recv().unwrap();
                });
            }
        });
        let dt = t0.elapsed().as_secs_f64();
        let total_frames = (n_streams * frames_per_stream) as f64;
        let mean_batch = engine.metrics().batch_size.summary().mean;
        println!(
            "max_batch={max_batch:<3} {total_frames:>6} frames in {dt:>6.3}s → {:>9.0} frames/s  (mean batch {:.2}, lane occupancy {:.2}, evictions {})",
            total_frames / dt,
            mean_batch,
            engine.metrics().lane_occupancy.summary().mean,
            *engine.metrics().evictions.lock().unwrap(),
        );
        throughput_rows.push((max_batch, total_frames / dt, mean_batch));
    }

    // (d) saturation: streams ≫ lanes with mixed priority — the quantum
    // scheduler's regime.  Half the clients are never-idle bulk streams
    // (long utterances, shallow pending queues keep them saturated); the
    // other half are interactive newcomers arriving into a fully-held
    // arena.  Records first-frame wait (admission → first posterior, the
    // preemption-bound latency) and per-tick frame latency percentiles.
    println!("\n== saturation: oversubscribed lanes, mixed priority (quantum scheduler) ==");
    let mut saturation_rows: Vec<(usize, f64, f64, f64, f64, u64)> = Vec::new();
    let lanes = 4usize;
    for factor in [2usize, 4] {
        let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
        let cfg = EngineConfig {
            policy: BatchPolicy { max_batch: lanes, deadline: std::time::Duration::from_millis(1) },
            decode_workers: 2,
            max_pending_frames: 64,
            quantum: QuantumPolicy { quantum_ticks: 8 },
            ..EngineConfig::default()
        };
        let engine = Arc::new(Engine::start(model, decoder.clone(), cfg));
        let n_streams = lanes * factor;
        let bulk_frames = 300usize;
        let ia_frames = 60usize;
        let mut bulk_frame = vec![0f32; spec::FEAT_DIM * bulk_frames];
        rng.fill_normal(&mut bulk_frame);
        let mut ia_frame = vec![0f32; spec::FEAT_DIM * ia_frames];
        rng.fill_normal(&mut ia_frame);
        std::thread::scope(|scope| {
            for s in 0..n_streams {
                let engine = engine.clone();
                let (frame, prio) = if s % 2 == 0 {
                    (bulk_frame.clone(), Priority::Bulk)
                } else {
                    (ia_frame.clone(), Priority::Interactive)
                };
                scope.spawn(move || {
                    // Interactive newcomers arrive after bulk holds lanes.
                    if prio == Priority::Interactive {
                        std::thread::sleep(std::time::Duration::from_millis(30));
                    }
                    let (id, rx) = engine
                        .try_open_stream(StreamOptions { model: 0, priority: prio })
                        .expect("admission");
                    engine.push_frames(id, &frame).unwrap();
                    engine.finish_stream(id).unwrap();
                    let _ = rx.recv().unwrap();
                });
            }
        });
        let ff = engine.metrics().first_frame_latency.summary();
        let tick = engine.metrics().frame_latency.summary();
        let preemptions = *engine.metrics().preemptions.lock().unwrap();
        println!(
            "oversub {factor}×  first-frame p50 {:.2}ms p99 {:.2}ms  per-tick p50 {:.2}ms \
             p99 {:.2}ms  preemptions {preemptions}",
            ff.p50, ff.p99, tick.p50, tick.p99,
        );
        saturation_rows.push((factor, ff.p50, ff.p99, tick.p50, tick.p99, preemptions));
    }

    // (e) fleet churn: model A saturated by never-idle bulk producers
    // while a second model is hot-loaded, serves one interactive
    // utterance, and is drained out — repeatedly.  Records load→ready
    // latency (admin ack: arena built on the worker), first-result
    // latency on the fresh model, drain latency, and whether the base
    // model's tail latency survives the churn.
    println!("\n== fleet churn: hot model load/unload under load ==");
    let churn_cycles = 8usize;
    let (churn_load_p50, churn_drain_p50, churn_first_p50, churn_tick_p99);
    {
        let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
        let cfg = EngineConfig {
            policy: BatchPolicy { max_batch: 4, deadline: std::time::Duration::from_millis(1) },
            decode_workers: 2,
            max_pending_frames: 64,
            quantum: QuantumPolicy { quantum_ticks: 8 },
            ..EngineConfig::default()
        };
        let engine = Arc::new(Engine::start(model, decoder.clone(), cfg));
        let stop = Arc::new(AtomicBool::new(false));
        let mut load_ms: Vec<f64> = Vec::new();
        let mut first_ms: Vec<f64> = Vec::new();
        let mut drain_ms: Vec<f64> = Vec::new();
        let mut base_chunk = vec![0f32; spec::FEAT_DIM * 16];
        rng.fill_normal(&mut base_chunk);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let engine = engine.clone();
                let stop = stop.clone();
                let chunk = base_chunk.clone();
                scope.spawn(move || {
                    let (id, rx) = engine
                        .try_open_stream(StreamOptions { model: 0, priority: Priority::Bulk })
                        .expect("admission");
                    while !stop.load(Ordering::SeqCst) {
                        engine.push_frames(id, &chunk).unwrap();
                    }
                    engine.finish_stream(id).unwrap();
                    let _ = rx.recv().unwrap();
                });
            }
            std::thread::sleep(std::time::Duration::from_millis(200));
            let mut utt = vec![0f32; spec::FEAT_DIM * 20];
            rng.fill_normal(&mut utt);
            for round in 0..churn_cycles {
                let qam_b = random_qam(2, 24, Some(12));
                let mb = Arc::new(AcousticModel::from_qam(&qam_b, ExecMode::Quant).unwrap());
                let t0 = std::time::Instant::now();
                let id = engine
                    .load_model_named(
                        format!("churn{round}"),
                        mb,
                        ModelParams { weight: 1, lanes: Some(2) },
                    )
                    .expect("hot load");
                load_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                let t1 = std::time::Instant::now();
                let (sid, rx) = engine
                    .try_open_stream(StreamOptions { model: id, priority: Priority::Interactive })
                    .expect("churn admission");
                engine.push_frames(sid, &utt).unwrap();
                engine.finish_stream(sid).unwrap();
                let _ = rx.recv().unwrap();
                first_ms.push(t1.elapsed().as_secs_f64() * 1e3);
                let t2 = std::time::Instant::now();
                engine.unload_model(id).expect("unload");
                drain_ms.push(t2.elapsed().as_secs_f64() * 1e3);
            }
            stop.store(true, Ordering::SeqCst);
        });
        let p50 = |v: &mut Vec<f64>| {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        churn_load_p50 = p50(&mut load_ms);
        churn_first_p50 = p50(&mut first_ms);
        churn_drain_p50 = p50(&mut drain_ms);
        // Engine-wide per-frame (enqueue→step) latency p99 across the
        // whole churn run — base + churned models' frames, the same
        // frame_latency histogram the saturation section reports as
        // tick_p99_ms; the serving-tail view, not base-model-isolated.
        churn_tick_p99 = engine.metrics().frame_latency.summary().p99;
        println!(
            "{churn_cycles} load/serve/unload cycles under saturation: load p50 \
             {churn_load_p50:.2}ms  utterance p50 {churn_first_p50:.2}ms  drain p50 \
             {churn_drain_p50:.2}ms  engine-wide per-tick p99 {churn_tick_p99:.2}ms  \
             (loads {} unloads {})",
            *engine.metrics().model_loads.lock().unwrap(),
            *engine.metrics().model_unloads.lock().unwrap(),
        );
    }

    // (f) weighted shares: two saturated models, weight ratios 1:1 and
    // 4:1 — the measured per-model frame split must track the configured
    // ratio (sched::weights DRR over the tick budget).
    println!("\n== weighted per-model shares under saturation ==");
    let mut share_rows: Vec<(u32, u32, f64)> = Vec::new();
    for weights in [[1u32, 1u32], [4, 1]] {
        let model_a = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
        let model_b = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
        let mut registry = ModelRegistry::new();
        registry.register_named("heavy", model_a);
        registry.register_named("light", model_b);
        let mut cfg = EngineConfig {
            policy: BatchPolicy { max_batch: 4, deadline: std::time::Duration::from_millis(1) },
            decode_workers: 2,
            max_pending_frames: 64,
            quantum: QuantumPolicy { quantum_ticks: 8 },
            ..EngineConfig::default()
        };
        cfg.model_weights = weights.to_vec();
        let engine = Arc::new(Engine::start_registry(registry, decoder.clone(), cfg));
        let stop = Arc::new(AtomicBool::new(false));
        std::thread::scope(|scope| {
            for m in 0..2usize {
                for _ in 0..4 {
                    let engine = engine.clone();
                    let stop = stop.clone();
                    let mut chunk = vec![0f32; spec::FEAT_DIM * 16];
                    let mut r2 = Xoshiro256::new(77 + m as u64);
                    r2.fill_normal(&mut chunk);
                    scope.spawn(move || {
                        let (id, rx) = engine
                            .try_open_stream(StreamOptions { model: m, priority: Priority::Bulk })
                            .expect("admission");
                        while !stop.load(Ordering::SeqCst) {
                            engine.push_frames(id, &chunk).unwrap();
                        }
                        engine.finish_stream(id).unwrap();
                        let _ = rx.recv().unwrap();
                    });
                }
            }
            std::thread::sleep(std::time::Duration::from_millis(200));
            let f0 = {
                let pm = engine.metrics().per_model.lock().unwrap();
                (pm[0].frames, pm[1].frames)
            };
            std::thread::sleep(std::time::Duration::from_millis(800));
            let f1 = {
                let pm = engine.metrics().per_model.lock().unwrap();
                (pm[0].frames, pm[1].frames)
            };
            stop.store(true, Ordering::SeqCst);
            let ratio = (f1.0 - f0.0) as f64 / ((f1.1 - f0.1).max(1)) as f64;
            println!(
                "weights {}:{}  measured frame share {:.2}:1",
                weights[0], weights[1], ratio
            );
            share_rows.push((weights[0], weights[1], ratio));
        });
    }

    // (g) tick breakdown: the whole-tick cost split — AM step vs decode
    // vs frontend — measured on the PCM path (`push_audio`) at 32
    // streams, so the "make the whole tick fast" claim is recorded, not
    // just the GEMMs.  Shares are of summed per-stage compute time (the
    // stages run on different threads, so they don't sum to wall clock).
    println!("\n== tick breakdown: AM vs decode vs frontend (32 PCM streams) ==");
    let (tick_am_s, tick_decode_s, tick_frontend_s);
    {
        let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
        let cfg = EngineConfig {
            policy: BatchPolicy {
                max_batch: 32,
                deadline: std::time::Duration::from_millis(2),
            },
            decode_workers: 2,
            max_pending_frames: 128,
            ..EngineConfig::default()
        };
        let engine = Arc::new(Engine::start(model, decoder.clone(), cfg));
        let n_streams = 32usize;
        let secs = 4.0f64;
        let n = (secs * spec::SAMPLE_RATE as f64) as usize;
        let mut wave = vec![0f32; n];
        let mut r2 = Xoshiro256::new(0x71CC);
        r2.fill_normal(&mut wave);
        for (i, v) in wave.iter_mut().enumerate() {
            let t = i as f64 / spec::SAMPLE_RATE as f64;
            *v = *v * 0.02 + (2.0 * std::f64::consts::PI * 700.0 * t).sin() as f32 * 0.3;
        }
        std::thread::scope(|scope| {
            for _ in 0..n_streams {
                let engine = engine.clone();
                let wave = wave.clone();
                scope.spawn(move || {
                    let (id, rx) = engine.open_stream();
                    // 80 ms PCM chunks, the live-dictation cadence.
                    for chunk in wave.chunks(640) {
                        engine.push_audio(id, chunk).unwrap();
                    }
                    engine.finish_stream(id).unwrap();
                    let _ = rx.recv().unwrap();
                });
            }
        });
        let (am_s, decode_s, frontend_s) = engine.metrics().tick_breakdown();
        let total = (am_s + decode_s + frontend_s).max(1e-12);
        println!(
            "  am {:.3}s ({:.1}%)  decode {:.3}s ({:.1}%)  frontend {:.3}s ({:.1}%)  \
             over {:.0}s of audio × {n_streams} streams",
            am_s,
            100.0 * am_s / total,
            decode_s,
            100.0 * decode_s / total,
            frontend_s,
            100.0 * frontend_s / total,
            secs,
        );
        tick_am_s = am_s;
        tick_decode_s = decode_s;
        tick_frontend_s = frontend_s;
    }

    // (h) the overload-control plane under deliberate abuse.  Engine A
    // (no faults) records the clean interactive baseline and the cost of
    // a canaried zero-downtime swap; engine B runs the same config with
    // a scripted plan forcing `overload_tick` on its first flushes, so
    // the brownout controller walks shed (stage 1) → admission rejection
    // (stage 2) → recovery while a Bulk flood and paced interactive
    // utterances fight over 4 lanes.  Knockers probe admission every few
    // ms throughout: the longest success-to-success gap is the outage
    // the brownout (or the swap) actually cost newcomers.
    println!("\n== overload: brownout shed/reject/recover + swap admission gap ==");
    let overload_json: String;
    {
        // Forced flush arrivals: enough to pin stage 2 for a measurable
        // window, few enough that the heavy phase itself consumes most
        // of them (leftovers drain at the 300 ms recovery trickle).
        const OV_FORCED: usize = 60;
        fn pct(v: &mut [f64], q: f64) -> f64 {
            if v.is_empty() {
                return 0.0;
            }
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[((v.len() - 1) as f64 * q) as usize]
        }
        // Pace one 40-frame utterance on an already-open stream (2
        // frames every 8 ms, the live-dictation cadence) and record its
        // finalize latency.  Push/finish errors mean the stream was shed
        // mid-flight — the sample is simply dropped.
        fn pump(
            engine: &Engine,
            id: u64,
            rx: &std::sync::mpsc::Receiver<quantasr::coordinator::FinalResult>,
            seed: u64,
            out: &Mutex<Vec<f64>>,
        ) {
            let mut frames = vec![0f32; 40 * spec::FEAT_DIM];
            Xoshiro256::new(seed).fill_normal(&mut frames);
            for chunk in frames.chunks(2 * spec::FEAT_DIM) {
                if engine.push_frames(id, chunk).is_err() {
                    return;
                }
                std::thread::sleep(Duration::from_millis(8));
            }
            if engine.finish_stream(id).is_err() {
                return;
            }
            if let Ok(fin) = rx.recv() {
                out.lock().unwrap().push(fin.finalize_latency.as_secs_f64() * 1e3);
            }
        }
        // A rejected open (brownout window) drops the sample — the
        // knocker is what counts rejections.
        fn utter(engine: &Engine, seed: u64, out: &Mutex<Vec<f64>>) {
            if let Ok((id, rx)) = engine
                .try_open_stream(StreamOptions { model: 0, priority: Priority::Interactive })
            {
                pump(engine, id, &rx, seed, out);
            }
        }
        let mk_cfg = |faults: Option<Arc<FaultPlan>>| EngineConfig {
            policy: BatchPolicy { max_batch: 4, deadline: Duration::from_millis(25) },
            decode_workers: 2,
            max_pending_frames: 64,
            quantum: QuantumPolicy { quantum_ticks: 8 },
            // Hermetic against ambient env (the CI overload job pins
            // QUANTASR_FAULTS for the chaos step; nothing may leak here).
            stream_idle: None,
            stream_deadline: None,
            faults,
            mem_budget: None,
            ..EngineConfig::default()
        };

        // --- engine A: clean baseline, then a swap under a knocker ---
        let (mut before, swap_ms, swap_fails, swap_gap_ms);
        {
            let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
            let engine = Arc::new(Engine::start(model, decoder.clone(), mk_cfg(None)));
            let lat = Mutex::new(Vec::new());
            std::thread::scope(|scope| {
                for t in 0..4u64 {
                    let engine = engine.clone();
                    let lat = &lat;
                    scope.spawn(move || {
                        for u in 0..2u64 {
                            utter(&engine, 0xA000 + t * 8 + u, lat);
                        }
                    });
                }
            });
            before = lat.into_inner().unwrap();
            let stop = AtomicBool::new(false);
            let (fails, gap_ms, t_swap) = std::thread::scope(|scope| {
                let knock = {
                    let engine = engine.clone();
                    let stop = &stop;
                    scope.spawn(move || {
                        let (mut fails, mut gap_ms) = (0u64, 0f64);
                        let mut last_ok: Option<Instant> = None;
                        while !stop.load(Ordering::SeqCst) {
                            match engine.try_open_stream(StreamOptions {
                                model: 0,
                                priority: Priority::Interactive,
                            }) {
                                Ok((id, rx)) => {
                                    let now = Instant::now();
                                    if let Some(prev) = last_ok {
                                        gap_ms =
                                            gap_ms.max((now - prev).as_secs_f64() * 1e3);
                                    }
                                    last_ok = Some(now);
                                    let _ = engine.finish_stream(id);
                                    let _ = rx.recv();
                                }
                                Err(_) => fails += 1,
                            }
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        (fails, gap_ms)
                    })
                };
                std::thread::sleep(Duration::from_millis(30));
                let replacement =
                    Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
                let t0 = Instant::now();
                engine
                    .swap_model(0, replacement, ModelParams::default())
                    .expect("clean swap must succeed");
                let t_swap = t0.elapsed().as_secs_f64() * 1e3;
                std::thread::sleep(Duration::from_millis(30));
                stop.store(true, Ordering::SeqCst);
                let (fails, gap_ms) = knock.join().unwrap();
                (fails, gap_ms, t_swap)
            });
            swap_ms = t_swap;
            swap_fails = fails;
            swap_gap_ms = gap_ms;
        }

        // --- engine B: forced brownout window ---
        let rules = (1..=OV_FORCED)
            .map(|i| format!("overload_tick@{i}"))
            .collect::<Vec<_>>()
            .join(",");
        let plan = Arc::new(FaultPlan::parse(&format!("1009:{rules}")).unwrap());
        let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
        let engine = Arc::new(Engine::start(model, decoder.clone(), mk_cfg(Some(plan))));
        // Open everything while the engine is quiescent: no pending
        // frames ⇒ no flushes ⇒ the forced window hasn't started, so
        // every admission below lands on brownout stage 0.
        let open = |priority: Priority| {
            engine
                .try_open_stream(StreamOptions { model: 0, priority })
                .expect("quiescent admission")
        };
        let (anchor_id, anchor_rx) = open(Priority::Interactive);
        let inter: Vec<_> = (0..8).map(|_| open(Priority::Interactive)).collect();
        let bulk: Vec<_> = (0..6).map(|_| open(Priority::Bulk)).collect();
        let stop_flood = AtomicBool::new(false);
        let stop_knock = AtomicBool::new(false);
        let during = Mutex::new(Vec::new());
        let mut recovery_ms = 0f64;
        let (rejects_seen, outage_ms) = std::thread::scope(|scope| {
            for (i, (id, rx)) in bulk.into_iter().enumerate() {
                let engine = engine.clone();
                let stop_flood = &stop_flood;
                let mut chunk = vec![0f32; spec::FEAT_DIM * 16];
                Xoshiro256::new(0xB000 + i as u64).fill_normal(&mut chunk);
                scope.spawn(move || {
                    // Runs until shed ("unknown stream" after the cancel)
                    // or told to stop; backpressure paces the loop.
                    while !stop_flood.load(Ordering::SeqCst)
                        && engine.push_frames(id, &chunk).is_ok()
                    {}
                    let _ = engine.finish_stream(id);
                    let _ = rx.recv();
                });
            }
            let pumps: Vec<_> = inter
                .into_iter()
                .enumerate()
                .map(|(i, (id, rx))| {
                    let engine = engine.clone();
                    let during = &during;
                    scope.spawn(move || {
                        pump(&engine, id, &rx, 0xD000 + i as u64, during)
                    })
                })
                .collect();
            let knock = {
                let engine = engine.clone();
                let stop_knock = &stop_knock;
                scope.spawn(move || {
                    let (mut rejects, mut outage_ms) = (0u64, 0f64);
                    let mut last_ok: Option<Instant> = None;
                    while !stop_knock.load(Ordering::SeqCst) {
                        match engine.try_open_stream(StreamOptions {
                            model: 0,
                            priority: Priority::Interactive,
                        }) {
                            Ok((id, rx)) => {
                                let now = Instant::now();
                                if let Some(prev) = last_ok {
                                    outage_ms =
                                        outage_ms.max((now - prev).as_secs_f64() * 1e3);
                                }
                                last_ok = Some(now);
                                let _ = engine.finish_stream(id);
                                let _ = rx.recv();
                            }
                            Err(RejectReason::Brownout) => rejects += 1,
                            Err(_) => {}
                        }
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    (rejects, outage_ms)
                })
            };
            for p in pumps {
                let _ = p.join();
            }
            // Recovery: trickle one frame every 300 ms on the anchor
            // (gap > the brownout controller's 250 ms calm threshold ⇒
            // ratio 0) until the stage returns to 0.  Each trickle also
            // drains one leftover forced arrival, so this terminates.
            let t0 = Instant::now();
            let mut frame = vec![0f32; spec::FEAT_DIM];
            Xoshiro256::new(0xF00D).fill_normal(&mut frame);
            for _ in 0..80 {
                if engine.overload_info().brownout_stage == 0 {
                    break;
                }
                let _ = engine.push_frames(anchor_id, &frame);
                std::thread::sleep(Duration::from_millis(300));
            }
            recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
            stop_flood.store(true, Ordering::SeqCst);
            stop_knock.store(true, Ordering::SeqCst);
            knock.join().unwrap()
        });
        engine.finish_stream(anchor_id).expect("anchor outlives the brownout");
        let _ = anchor_rx.recv();
        // Post-recovery interactive traffic on the same engine.
        let after = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let engine = engine.clone();
                let after = &after;
                scope.spawn(move || {
                    for u in 0..2u64 {
                        utter(&engine, 0xE000 + t * 8 + u, after);
                    }
                });
            }
        });
        let m = engine.metrics();
        let shed = *m.shed_streams.lock().unwrap();
        let entries = *m.brownout_entries.lock().unwrap();
        let exits = *m.brownout_exits.lock().unwrap();
        let brownout_rejects = *m.brownout_rejects.lock().unwrap();
        let mut during = during.into_inner().unwrap();
        let mut after = after.into_inner().unwrap();
        let (before_p50, before_p99) = (pct(&mut before, 0.50), pct(&mut before, 0.99));
        let (during_p50, during_p99) = (pct(&mut during, 0.50), pct(&mut during, 0.99));
        let (after_p50, after_p99) = (pct(&mut after, 0.50), pct(&mut after, 0.99));
        println!(
            "  finalize p99 ms  before {before_p99:.2}  during {during_p99:.2}  \
             after {after_p99:.2}   ({} / {} / {} samples)",
            before.len(),
            during.len(),
            after.len(),
        );
        println!(
            "  shed {shed}  entries {entries}  exits {exits}  rejects {brownout_rejects} \
             (knocker saw {rejects_seen})  admission outage {outage_ms:.1} ms  \
             recovery {recovery_ms:.1} ms"
        );
        println!(
            "  swap {swap_ms:.1} ms  admission fails during swap {swap_fails}  \
             max admission gap {swap_gap_ms:.1} ms"
        );
        let mut ov = String::new();
        let _ = write!(
            ov,
            "{{\"before_p50_ms\": {before_p50:.2}, \"before_p99_ms\": {before_p99:.2}, \
             \"during_p50_ms\": {during_p50:.2}, \"during_p99_ms\": {during_p99:.2}, \
             \"after_p50_ms\": {after_p50:.2}, \"after_p99_ms\": {after_p99:.2}, \
             \"shed_streams\": {shed}, \"brownout_entries\": {entries}, \
             \"brownout_exits\": {exits}, \"brownout_rejects\": {brownout_rejects}, \
             \"max_admission_outage_ms\": {outage_ms:.1}, \"recovery_ms\": {recovery_ms:.1}, \
             \"swap_ms\": {swap_ms:.1}, \"swap_admission_fails\": {swap_fails}, \
             \"swap_max_admission_gap_ms\": {swap_gap_ms:.1}}}"
        );
        overload_json = ov;
    }

    // (i) flight-recorder overhead: the same saturated 32-stream workload
    // with the recorder off, then on.  Per-tick latency comes from the
    // engine's own frame_latency histogram; events/s from the recorder's
    // ring heads.  The two runs share a process, so `set_enabled` is the
    // only variable (QUANTASR_TRACE only sets the boot default).
    println!("\n== flight recorder: tracing off vs on (32 streams, saturated) ==");
    {
        use quantasr::obs;
        let run = |traced: bool| -> (f64, f64, f64, usize) {
            obs::set_enabled(traced);
            let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
            let cfg = EngineConfig {
                policy: BatchPolicy {
                    max_batch: 32,
                    deadline: std::time::Duration::from_millis(2),
                },
                decode_workers: 2,
                max_pending_frames: 128,
                ..EngineConfig::default()
            };
            let engine = Arc::new(Engine::start(model, decoder.clone(), cfg));
            let n_streams = 32usize;
            let frames_per_stream = 100usize;
            let mut frame = vec![0f32; spec::FEAT_DIM * frames_per_stream];
            Xoshiro256::new(0x7AACE).fill_normal(&mut frame);
            let t0 = Instant::now();
            std::thread::scope(|scope| {
                for _ in 0..n_streams {
                    let engine = engine.clone();
                    let frame = frame.clone();
                    scope.spawn(move || {
                        let (id, rx) = engine.open_stream();
                        engine.push_frames(id, &frame).unwrap();
                        engine.finish_stream(id).unwrap();
                        let _ = rx.recv().unwrap();
                    });
                }
            });
            let dt = t0.elapsed().as_secs_f64();
            let tick = engine.metrics().frame_latency.summary();
            let events = obs::snapshot_engine(engine.obs_id()).len();
            ((n_streams * frames_per_stream) as f64 / dt, tick.p50, tick.p99, events)
        };
        let (fps_off, p50_off, p99_off, ev_off) = run(false);
        let (fps_on, p50_on, p99_on, ev_on) = run(true);
        obs::set_enabled(true); // leave the recorder in its always-on default
        let p99_overhead = 100.0 * (p99_on - p99_off) / p99_off.max(1e-9);
        println!(
            "  off: {fps_off:>9.0} frames/s  tick p50 {p50_off:.3}ms p99 {p99_off:.3}ms  \
             ({ev_off} events)"
        );
        println!(
            "  on:  {fps_on:>9.0} frames/s  tick p50 {p50_on:.3}ms p99 {p99_on:.3}ms  \
             ({ev_on} events)"
        );
        println!("  → tracing-on tick p99 overhead {p99_overhead:+.2}%");
        let mut tj = String::new();
        let _ = write!(
            tj,
            "{{\n  \"bench\": \"trace_overhead\",\n  \
             \"off\": {{\"frames_per_s\": {fps_off:.1}, \"tick_p50_ms\": {p50_off:.3}, \
             \"tick_p99_ms\": {p99_off:.3}}},\n  \
             \"on\": {{\"frames_per_s\": {fps_on:.1}, \"tick_p50_ms\": {p50_on:.3}, \
             \"tick_p99_ms\": {p99_on:.3}, \"events_recorded\": {ev_on}}},\n  \
             \"tick_p99_overhead_pct\": {p99_overhead:.2}\n}}"
        );
        match std::fs::write("BENCH_trace.json", &tj) {
            Ok(()) => println!("  wrote BENCH_trace.json"),
            Err(e) => eprintln!("  could not write BENCH_trace.json: {e}"),
        }
    }

    // Emit BENCH_engine.json so the perf trajectory is recorded across PRs.
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"engine\",\n  \"results\": [\n");
    for (i, m) in recorded.iter().enumerate() {
        let comma = if i + 1 < recorded.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"name\": \"{}\", \"mean_ns\": {:.1}, \"p50_ns\": {:.1}, \"p99_ns\": {:.1}, \"iters\": {}}}{comma}",
            m.name, m.mean_ns, m.p50_ns, m.p99_ns, m.iters
        );
    }
    json.push_str("  ],\n  \"engine_throughput\": [\n");
    for (i, (mb, fps, mean_batch)) in throughput_rows.iter().enumerate() {
        let comma = if i + 1 < throughput_rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"max_batch\": {mb}, \"frames_per_s\": {fps:.1}, \"mean_batch\": {mean_batch:.2}}}{comma}"
        );
    }
    json.push_str("  ],\n  \"saturation\": [\n");
    for (i, (factor, ffp50, ffp99, tp50, tp99, preempts)) in
        saturation_rows.iter().enumerate()
    {
        let comma = if i + 1 < saturation_rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"oversubscription\": {factor}, \"first_frame_p50_ms\": {ffp50:.2}, \
             \"first_frame_p99_ms\": {ffp99:.2}, \"tick_p50_ms\": {tp50:.2}, \
             \"tick_p99_ms\": {tp99:.2}, \"preemptions\": {preempts}}}{comma}"
        );
    }
    json.push_str("  ],\n");
    let _ = writeln!(
        json,
        "  \"churn\": {{\"cycles\": {churn_cycles}, \"load_p50_ms\": {churn_load_p50:.2}, \
         \"utterance_p50_ms\": {churn_first_p50:.2}, \"drain_p50_ms\": {churn_drain_p50:.2}, \
         \"tick_p99_ms\": {churn_tick_p99:.2}}},"
    );
    json.push_str("  \"weighted_shares\": [\n");
    for (i, (wa, wb, ratio)) in share_rows.iter().enumerate() {
        let comma = if i + 1 < share_rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"weights\": \"{wa}:{wb}\", \"measured_frame_ratio\": {ratio:.2}}}{comma}"
        );
    }
    let tick_total = (tick_am_s + tick_decode_s + tick_frontend_s).max(1e-12);
    let _ = writeln!(
        json,
        "  ],\n  \"tick_breakdown\": {{\"am_s\": {tick_am_s:.4}, \"decode_s\": \
         {tick_decode_s:.4}, \"frontend_s\": {tick_frontend_s:.4}, \"am_share\": {:.3}, \
         \"decode_share\": {:.3}, \"frontend_share\": {:.3}}},",
        tick_am_s / tick_total,
        tick_decode_s / tick_total,
        tick_frontend_s / tick_total,
    );
    let _ = writeln!(json, "  \"overload\": {overload_json}\n}}");
    match std::fs::write("BENCH_engine.json", &json) {
        Ok(()) => println!("\nwrote BENCH_engine.json"),
        Err(e) => eprintln!("\ncould not write BENCH_engine.json: {e}"),
    }
}
