//! Offline **stub** of the `xla` crate surface used by `quantasr`'s PJRT
//! path (`runtime::model_exec`).
//!
//! The real bindings wrap a prebuilt `xla_extension` C++ library that is
//! not available in this build image, so this crate provides the same API
//! shapes with constructors that fail at *runtime* ("xla unavailable")
//! instead of failing the *build*.  That keeps `--features pjrt` compiling
//! everywhere — the `AmBackend` implementation, the `pjrt-check` command
//! and the artifact tests all type-check — while real execution requires
//! swapping this path dependency for the actual bindings.
//!
//! Only the API surface `quantasr` uses is modelled; this is not a general
//! xla binding.

use std::fmt;
use std::path::Path;

/// Error type matching the real bindings' `Result<_, xla::Error>` shape.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} requires the real xla_extension bindings \
         (this build vendors rust/vendor/xla, an offline stub)"
    )))
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy + Default {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side tensor value (the only part of the stub that actually works;
/// it is pure data and needs no native library).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    shape: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), shape: vec![data.len() as i64] }
    }

    /// Reshape without changing the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), shape: dims.to_vec() })
    }

    pub fn shape(&self) -> &[i64] {
        &self.shape
    }

    /// Destructure a tuple literal.  The stub never produces tuples (no
    /// execution), so this is only reachable with real bindings.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple on an executed result")
    }

    /// Read the elements back.  f32 data round-trips; other element types
    /// only exist on executed results, which the stub cannot produce.
    pub fn to_vec<T: NativeType + 'static>(&self) -> Result<Vec<T>> {
        // The stub stores f32 only; a same-size transmute-free copy is
        // possible just for f32.
        if std::any::TypeId::of::<T>() == std::any::TypeId::of::<f32>() {
            let mut out: Vec<T> = Vec::with_capacity(self.data.len());
            for &v in &self.data {
                // T == f32 here; go through a trivially-checked cast.
                let as_t: T = unsafe { std::mem::transmute_copy(&v) };
                out.push(as_t);
            }
            Ok(out)
        } else {
            unavailable("Literal::to_vec for non-f32 element types")
        }
    }
}

/// Parsed HLO module (text format).  Parsing needs the native library.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT CPU client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer produced by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.shape(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn runtime_entry_points_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
    }
}
