//! The CI accuracy gate for in-situ requantization (`--isq`).
//!
//! Two tiers:
//!
//! - [`isq_accuracy_gate_and_bench`] always runs: it prices every scheme on
//!   a deterministic random model + sim-generated eval set — weight
//!   reconstruction RMS, posterior divergence vs the f32 path, greedy
//!   phone-LER deltas — asserts the documented ceilings, and writes
//!   `BENCH_quant.json` (CI uploads it) including the batch-32 i4-vs-u8
//!   GEMM throughput ratio, so the accuracy/speed trade-off of the int4
//!   ladder is recorded next to the WER evidence.
//! - [`isq_wer_gate_on_trained_model`] runs when `make artifacts` models
//!   exist: the real decoder-in-the-loop WER deltas vs f32 on the trained
//!   p24 grid, with the per-scheme WER ceilings CI enforces.
//!
//! Documented bounds (the gate):
//! - PerChannelU8 weight RMS ≤ PerMatrixU8 weight RMS on every matrix
//!   (finer granularity can only help).
//! - PerChannelI4 weight RMS ≤ 20× PerMatrixU8 (the 4-bit grid has 17×
//!   the step size; per-channel ranges claw some back).
//! - Trained-model WER: per-channel-u8 ≤ per-matrix-u8 + 2% absolute;
//!   per-channel-i4 ≤ f32 + 10% absolute.

mod common;

use std::fmt::Write as _;
use std::time::Instant;

use quantasr::decoder::{ctc, wer};
use quantasr::io::model_fmt::Tensor;
use quantasr::nn::{AcousticModel, ExecMode};
use quantasr::quant::gemm::{qgemm, Kernel, QScratch};
use quantasr::quant::{QMatrix, QuantScheme};
use quantasr::sim::dataset::{generate_split, Style};
use quantasr::sim::World;

const SCHEMES: [QuantScheme; 3] =
    [QuantScheme::PerMatrixU8, QuantScheme::PerChannelU8, QuantScheme::PerChannelI4];

/// RMS of `recover(quantize(w)) − w` for one scheme over one matrix.
fn recon_rms(w: &[f32], in_dim: usize, out_dim: usize, scheme: QuantScheme) -> f64 {
    let m = QMatrix::from_f32_math_layout_scheme(w, in_dim, out_dim, scheme);
    let r = m.recover_math_layout();
    (w.iter().zip(&r).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>() / w.len() as f64).sqrt()
}

#[test]
fn isq_accuracy_gate_and_bench() {
    let qam = common::random_model_seeded(2, 64, Some(32), 0x15_0A);
    let world = World::new();
    let utts = generate_split(6, 0xA11, &world, Style::Clean);

    // --- Weight reconstruction error per scheme, every 2-D tensor. ---
    let mut rms = [0.0f64; 3]; // summed over matrices, per scheme
    let mut mats = 0usize;
    for t in qam.tensors.values() {
        let shape = t.shape().to_vec();
        if shape.len() != 2 {
            continue;
        }
        mats += 1;
        let w = match t {
            Tensor::F32 { data, .. } => data.clone(),
            q => q.to_f32(),
        };
        let per_scheme: Vec<f64> =
            SCHEMES.iter().map(|&s| recon_rms(&w, shape[0], shape[1], s)).collect();
        // Finer granularity can only shrink the error (same 8-bit grid,
        // tighter ranges) — enforced per matrix, not just on average.
        assert!(
            per_scheme[1] <= per_scheme[0] * 1.0001 + 1e-12,
            "per-channel-u8 RMS {} > per-matrix-u8 RMS {} on a {shape:?} matrix",
            per_scheme[1],
            per_scheme[0]
        );
        assert!(
            per_scheme[2] <= per_scheme[0] * 20.0,
            "per-channel-i4 RMS {} blew past 20× the u8 baseline {} on {shape:?}",
            per_scheme[2],
            per_scheme[0]
        );
        for (acc, v) in rms.iter_mut().zip(&per_scheme) {
            *acc += v;
        }
    }
    assert!(mats >= 5, "random model should have several matrices");
    for v in rms.iter_mut() {
        *v /= mats as f64;
    }

    // --- Posterior divergence + greedy phone LER vs the f32 path. ---
    let mf = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
    let f32_lp: Vec<Vec<f32>> =
        utts.iter().map(|u| mf.forward_utt(&u.feats, u.num_frames)).collect();
    let ler_of = |lps: &[Vec<f32>]| -> f64 {
        let mut st = wer::EditStats::default();
        for (lp, u) in lps.iter().zip(&utts) {
            st.add(&wer::align(&ctc::greedy(lp, mf.num_labels()), &u.phones));
        }
        st.rate()
    };
    let f32_ler = ler_of(&f32_lp);
    // (max |Δ log p| ceiling, |Δ LER| ceiling) per scheme — the u8 bounds
    // mirror the nn::model close-to-float contract; i4 gets the coarser
    // documented budget.
    let budgets = [(1.5f32, 0.05f64), (1.5, 0.05), (6.0, 0.25)];
    let mut max_dlp = [0.0f32; 3];
    let mut lers = [0.0f64; 3];
    for (si, &scheme) in SCHEMES.iter().enumerate() {
        let mq = AcousticModel::from_qam_scheme(&qam, ExecMode::Quant, scheme).unwrap();
        let lps: Vec<Vec<f32>> =
            utts.iter().map(|u| mq.forward_utt(&u.feats, u.num_frames)).collect();
        for (lp, flp) in lps.iter().zip(&f32_lp) {
            for (a, b) in lp.iter().zip(flp) {
                max_dlp[si] = max_dlp[si].max((a - b).abs());
            }
        }
        lers[si] = ler_of(&lps);
        let (lp_bound, ler_bound) = budgets[si];
        assert!(
            max_dlp[si] < lp_bound,
            "{scheme:?}: max |Δ log p| {} ≥ ceiling {lp_bound}",
            max_dlp[si]
        );
        assert!(
            (lers[si] - f32_ler).abs() < ler_bound,
            "{scheme:?}: greedy LER {} drifted from f32 LER {f32_ler} past {ler_bound}",
            lers[si]
        );
    }

    // --- Batch-32 GEMM throughput, i4 vs u8, on the auto rung. ---
    // Small enough to stay cheap in debug builds; the CI quant-accuracy
    // job runs --release, where this ratio is the acceptance number.
    let (k, n, batch) = (256usize, 1024usize, 32usize);
    let wf: Vec<f32> = (0..k * n).map(|i| ((i * 2654435761) as f32).sin() * 0.05).collect();
    let x: Vec<f32> = (0..batch * k).map(|i| ((i * 40503) as f32).cos()).collect();
    let mut gemm_ns = [0.0f64; 3];
    for (si, &scheme) in SCHEMES.iter().enumerate() {
        let qm = QMatrix::from_f32_math_layout_scheme(&wf, k, n, scheme);
        let mut y = vec![0f32; batch * n];
        let mut scratch = QScratch::default();
        // warm-up, then best-of-5 (min filters scheduler noise)
        qgemm(&x, batch, &qm, None, &mut y, &mut scratch, Kernel::Auto, false);
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t0 = Instant::now();
            qgemm(&x, batch, &qm, None, &mut y, &mut scratch, Kernel::Auto, false);
            best = best.min(t0.elapsed().as_secs_f64() * 1e9);
        }
        gemm_ns[si] = best;
    }
    let i4_vs_u8 = gemm_ns[1] / gemm_ns[2];
    println!("i4 vs per-channel-u8 GEMM at batch {batch}: {i4_vs_u8:.2}×");

    // --- BENCH_quant.json: the accuracy/speed trade-off artifact. ---
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"quant\",\n  \"schemes\": [\n");
    for (si, &scheme) in SCHEMES.iter().enumerate() {
        let comma = if si + 1 < SCHEMES.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"scheme\": \"{}\", \"weight_rms\": {:.6e}, \
             \"max_dlogp\": {:.4}, \"greedy_ler\": {:.4}, \
             \"gemm_b32_ns\": {:.0}}}{comma}",
            scheme.name(),
            rms[si],
            max_dlp[si],
            lers[si],
            gemm_ns[si],
        );
    }
    let _ = writeln!(
        json,
        "  ],\n  \"f32_greedy_ler\": {f32_ler:.4},\n  \
         \"gemm\": {{\"batch\": {batch}, \"k\": {k}, \"n\": {n}, \
         \"i4_vs_pc_u8\": {i4_vs_u8:.3}}}\n}}"
    );
    match std::fs::write("BENCH_quant.json", &json) {
        Ok(()) => println!("wrote BENCH_quant.json"),
        Err(e) => eprintln!("could not write BENCH_quant.json: {e}"),
    }
}

#[test]
fn isq_wer_gate_on_trained_model() {
    use quantasr::decoder::DecoderConfig;
    use quantasr::eval::{build_decoder, evaluate};
    use quantasr::io::feat_fmt::read_feats;

    let Some(art) = common::artifacts() else { return };
    let utts = read_feats(art.join("data/eval_clean.feats")).unwrap();
    let utts = &utts[..32.min(utts.len())];
    let qam = quantasr::io::model_fmt::QamFile::load(art.join("models/p24.float.qam")).unwrap();
    let world = World::new();
    let decoder = build_decoder(&world, DecoderConfig::default());

    let mf = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
    let f32_wer = evaluate(&mf, &decoder, utts, 4).wer;
    let mut wers = [0.0f64; 3];
    for (si, &scheme) in SCHEMES.iter().enumerate() {
        let m = AcousticModel::from_qam_scheme(&qam, ExecMode::Quant, scheme).unwrap();
        wers[si] = evaluate(&m, &decoder, utts, 4).wer;
        println!("{}: WER {:.2}% (f32 {:.2}%)", scheme.name(), 100.0 * wers[si], 100.0 * f32_wer);
    }
    // The CI ceilings: finer u8 granularity must not cost accuracy, and
    // the 4-bit ladder must stay within its documented WER budget.
    assert!(
        wers[1] <= wers[0] + 0.02,
        "per-channel-u8 WER {} > per-matrix-u8 WER {} + 2%",
        wers[1],
        wers[0]
    );
    assert!(
        wers[2] <= f32_wer + 0.10,
        "per-channel-i4 WER {} > f32 WER {f32_wer} + 10% budget",
        wers[2]
    );
}
