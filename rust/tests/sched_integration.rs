//! Integration tests of the preemptive scheduler (no artifacts needed —
//! random models): starvation freedom under never-idle saturation,
//! preemption bit-exactness across kernel rungs and tick boundaries,
//! multi-model serving with per-model accounting, admission backpressure,
//! hot model load/unload churn, weighted per-model fairness, and the TCP
//! reject/priority/admin protocol.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use quantasr::coordinator::batcher::BatchPolicy;
use quantasr::coordinator::server::{serve, serve_with_loader, Client, ModelLoader};
use quantasr::coordinator::{Engine, EngineConfig};
use quantasr::decoder::DecoderConfig;
use quantasr::eval::build_decoder;
use quantasr::frontend::spec;
use quantasr::nn::{AcousticModel, ExecMode};
use quantasr::quant::QuantScheme;
use quantasr::sched::{
    AdmissionConfig, BudgetLedger, ModelParams, ModelRegistry, Priority, QuantumPolicy,
    RejectReason, StreamOptions,
};
use quantasr::sim::World;
use quantasr::util::rng::Xoshiro256;

fn frames(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::new(seed);
    let mut v = vec![0f32; n * spec::FEAT_DIM];
    for x in v.iter_mut() {
        *x = rng.normal() as f32;
    }
    v
}

fn sched_config(max_batch: usize, quantum_ticks: u32, max_pending: usize) -> EngineConfig {
    EngineConfig {
        policy: BatchPolicy { max_batch, deadline: Duration::from_millis(1) },
        decode_workers: 2,
        max_pending_frames: max_pending,
        quantum: QuantumPolicy { quantum_ticks },
        admission: AdmissionConfig::default(),
        // Never inherit a process-wide fault plan: this suite's engines
        // script no faults (a pinned QUANTASR_FAULTS belongs to the
        // chaos suite).
        faults: None,
        ..EngineConfig::default()
    }
}

fn greedy_ref(model: &AcousticModel, f: &[f32], n: usize) -> Vec<u32> {
    let lp = model.forward_utt(f, n);
    quantasr::decoder::ctc::greedy(&lp, model.num_labels())
}

/// The acceptance scenario: every lane held by a never-idle bulk stream
/// (the exact starvation hole the pre-scheduler engine documented), then
/// interactive newcomers arrive.  They must be scheduled (via quantum
/// preemption — no lane is ever free and no holder ever idles), and every
/// stream's output must be bit-identical to its unpreempted solo run.
#[test]
fn interactive_streams_not_starved_by_never_idle_bulk() {
    let lanes = 2usize;
    let qam = common::random_model(2, 16, Some(8));
    let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    // Deep pending queues (32) so bulk producers blocked on backpressure
    // keep their streams never-idle; quantum 3 bounds the newcomer wait.
    let eng = Arc::new(Engine::start(model.clone(), decoder, sched_config(lanes, 3, 32)));

    let bulk_frames = 400usize;
    let bulk_content: Vec<Vec<f32>> =
        (0..lanes).map(|s| frames(bulk_frames, 900 + s as u64)).collect();
    let bulk_want: Vec<Vec<u32>> =
        bulk_content.iter().map(|f| greedy_ref(&model, f, bulk_frames)).collect();
    let ia_frames = 12usize;
    let ia_content = frames(ia_frames, 777);
    let ia_want = greedy_ref(&model, &ia_content, ia_frames);

    std::thread::scope(|scope| {
        // One never-idle bulk stream per lane: push_frames blocks on
        // backpressure, so the queue stays full until fully consumed.
        let mut bulk_rx = Vec::new();
        for (s, content) in bulk_content.iter().enumerate() {
            let (id, rx) = eng
                .try_open_stream(StreamOptions { model: 0, priority: Priority::Bulk })
                .expect("bulk admission");
            bulk_rx.push((rx, s));
            let eng = eng.clone();
            scope.spawn(move || {
                eng.push_frames(id, content).unwrap();
                eng.finish_stream(id).unwrap();
            });
        }
        // Let the bulk streams occupy every lane.
        std::thread::sleep(Duration::from_millis(100));
        // 4× oversubscription: 2·lanes interactive newcomers on top of
        // the lane-holding bulk streams.
        let mut ia_rx = Vec::new();
        for k in 0..2 * lanes {
            let (id, rx) = eng
                .try_open_stream(StreamOptions { model: 0, priority: Priority::Interactive })
                .expect("interactive admission");
            eng.push_frames(id, &ia_content).unwrap();
            eng.finish_stream(id).unwrap();
            ia_rx.push((rx, k));
        }
        // Starvation bound: without preemption these recvs never return
        // (bulk holders never idle, lanes release only at drain).
        for (rx, k) in ia_rx {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap_or_else(|_| {
                panic!("interactive stream {k} starved behind never-idle bulk")
            });
            assert_eq!(r.num_frames, ia_frames);
            assert_eq!(r.phones, ia_want, "preemption changed interactive numerics");
        }
        assert!(
            *eng.metrics().preemptions.lock().unwrap() >= 1,
            "interactive progress without any preemption should be impossible here"
        );
        // The preempted bulk streams must drain to bit-identical results.
        for (rx, s) in bulk_rx {
            let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(r.num_frames, bulk_frames);
            assert_eq!(r.phones, bulk_want[s], "preemption changed bulk numerics");
        }
    });
    let report = eng.metrics().report();
    assert!(report.contains("preemptions="), "{report}");
    assert_eq!(*eng.metrics().sched_stalls.lock().unwrap(), 0);
}

/// Preemption bit-exactness across kernel rungs: streams forced through
/// constant quantum-boundary preemption (1 lane, several streams) must
/// produce output bit-identical to their solo runs on every rung, at
/// multiple quantum lengths (= preemption at different tick boundaries).
#[test]
fn preemption_bit_exact_across_kernel_rungs() {
    use quantasr::quant::gemm::Kernel;
    let qam = common::random_model(2, 16, Some(8));
    let n_streams = 3usize;
    let total = 20usize;
    let content: Vec<Vec<f32>> =
        (0..n_streams).map(|s| frames(total, 4000 + s as u64)).collect();
    for kernel in [Kernel::Scalar, Kernel::PackedScalar, Kernel::Auto] {
        for quantum in [1u32, 3] {
            let mut m = AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap();
            m.kernel = kernel;
            let model = Arc::new(m);
            let want: Vec<Vec<u32>> =
                content.iter().map(|f| greedy_ref(&model, f, total)).collect();
            let decoder = Arc::new(build_decoder(
                &World::new(),
                DecoderConfig { beam: 4, ..Default::default() },
            ));
            let eng = Engine::start(model.clone(), decoder, sched_config(1, quantum, 32));
            let mut rxs = Vec::new();
            for f in &content {
                let (id, rx) = eng.open_stream();
                eng.push_frames(id, f).unwrap();
                eng.finish_stream(id).unwrap();
                rxs.push(rx);
            }
            for (rx, want_phones) in rxs.into_iter().zip(&want) {
                let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
                assert_eq!(r.num_frames, total);
                assert_eq!(
                    &r.phones, want_phones,
                    "kernel {kernel:?} quantum {quantum}: preemption changed numerics"
                );
            }
            // 3 streams share 1 lane and none ever idles mid-utterance:
            // rotation requires preemption.
            assert!(*eng.metrics().preemptions.lock().unwrap() >= 1);
        }
    }
}

/// Two models in one engine process: streams on each are served
/// concurrently by the same scheduler/worker, results match each model's
/// solo reference, and per-model lane accounting is reported.
#[test]
fn two_models_share_one_engine_with_per_model_metrics() {
    let qam_a = common::random_model_seeded(2, 16, Some(8), 0xA11CE);
    let qam_b = common::random_model_seeded(2, 12, Some(6), 0xB0B);
    let model_a = Arc::new(AcousticModel::from_qam(&qam_a, ExecMode::Quant).unwrap());
    let model_b = Arc::new(AcousticModel::from_qam(&qam_b, ExecMode::Quant).unwrap());
    let mut registry = ModelRegistry::new();
    assert_eq!(registry.register_named("model-a", model_a.clone()), 0);
    assert_eq!(registry.register_named("model-b", model_b.clone()), 1);
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let eng = Engine::start_registry(registry, decoder, sched_config(2, 4, 32));

    let per_model_streams = 3usize;
    let total = 15usize;
    let mut rxs = Vec::new();
    for s in 0..per_model_streams {
        for (midx, model) in [(0usize, &model_a), (1usize, &model_b)] {
            let f = frames(total, 7000 + (midx * 100 + s) as u64);
            let want = greedy_ref(model, &f, total);
            let (id, rx) = eng
                .try_open_stream(StreamOptions { model: midx, priority: Priority::Interactive })
                .expect("admission");
            eng.push_frames(id, &f).unwrap();
            eng.finish_stream(id).unwrap();
            rxs.push((rx, midx, want));
        }
    }
    for (rx, midx, want) in rxs {
        let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r.num_frames, total);
        assert_eq!(r.phones, want, "model {midx}: multi-model serving changed numerics");
    }
    let pm = eng.metrics().per_model.lock().unwrap();
    assert_eq!(pm.len(), 2);
    assert_eq!(pm[0].name, "model-a");
    assert_eq!(pm[1].name, "model-b");
    for stats in pm.iter() {
        assert_eq!(
            stats.frames,
            (per_model_streams * total) as u64,
            "every frame steps exactly once per model"
        );
        assert!(stats.ticks > 0);
        assert!(stats.occupancy() > 0.0 && stats.occupancy() <= 1.0);
    }
    drop(pm);
    let report = eng.metrics().report();
    assert!(report.contains("model[0] model-a"), "{report}");
    assert!(report.contains("model[1] model-b"), "{report}");
}

/// Admission control: beyond the live-stream cap new streams are rejected
/// with a reason (bounded queue, not unbounded growth), and capacity
/// frees up when streams drain.
#[test]
fn admission_rejects_beyond_cap_and_recovers() {
    let qam = common::random_model(2, 16, Some(8));
    let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let mut cfg = sched_config(2, 4, 32);
    cfg.admission = AdmissionConfig { max_live_streams: 2 };
    let eng = Engine::start(model, decoder, cfg);

    let (id_a, rx_a) = eng.try_open_stream(StreamOptions::default()).unwrap();
    let (id_b, rx_b) = eng.try_open_stream(StreamOptions::default()).unwrap();
    match eng.try_open_stream(StreamOptions::default()) {
        Err(RejectReason::Saturated { live, cap }) => {
            assert_eq!((live, cap), (2, 2));
        }
        other => panic!("expected saturation reject, got {other:?}"),
    }
    match eng.try_open_stream(StreamOptions { model: 7, ..Default::default() }) {
        Err(RejectReason::UnknownModel { model, loaded }) => {
            assert_eq!((model, loaded), (7, 1));
        }
        other => panic!("expected unknown-model reject, got {other:?}"),
    }
    assert_eq!(*eng.metrics().admission_rejects.lock().unwrap(), 2);
    // Drain both; the result implies the stream slot is gone, so
    // admission capacity is back.
    for (id, rx) in [(id_a, rx_a), (id_b, rx_b)] {
        eng.push_frames(id, &frames(4, id)).unwrap();
        eng.finish_stream(id).unwrap();
        rx.recv_timeout(Duration::from_secs(20)).unwrap();
    }
    assert!(eng.try_open_stream(StreamOptions::default()).is_ok());
}

/// The TCP protocol carries the QoS class ('P') and surfaces admission
/// rejects as 'R' frames with the reason, instead of hanging the client.
#[test]
fn server_rejects_over_tcp_with_reason() {
    let qam = common::random_model(2, 16, Some(8));
    let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let mut cfg = sched_config(2, 4, 32);
    cfg.admission = AdmissionConfig { max_live_streams: 1 };
    let engine = Arc::new(Engine::start(model, decoder, cfg));

    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv_engine = engine.clone();
    let srv_stop = stop.clone();
    let server = std::thread::spawn(move || {
        serve(srv_engine, "127.0.0.1:0", srv_stop, move |a| {
            let _ = addr_tx.send(a);
        })
        .expect("server failed");
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(10)).unwrap().to_string();

    // First client takes the only admission slot and holds it open.
    let mut c1 = Client::connect(&addr).unwrap();
    c1.set_priority(Priority::Interactive).unwrap();
    c1.send_audio(&[0.01f32; 800]).unwrap();
    std::thread::sleep(Duration::from_millis(200)); // let the open commit
    // Second client must be rejected with the saturation reason.
    let c2 = Client::connect(&addr).unwrap();
    let err = c2.finish().expect_err("second stream should be rejected");
    assert!(
        format!("{err:#}").contains("saturated"),
        "want saturation reject, got: {err:#}"
    );
    // The first client is unaffected.
    let r1 = c1.finish().expect("first stream serves normally");
    assert!(r1.server_latency_ms >= 0.0);
    assert!(*engine.metrics().admission_rejects.lock().unwrap() >= 1);

    stop.store(true, Ordering::SeqCst);
    server.join().unwrap();
}

/// The hot-churn acceptance scenario: model A is saturated at 2×
/// oversubscription by never-idle bulk streams (its lanes rotate through
/// quantum preemption the whole time) while a second model is hot-loaded,
/// serves an interactive utterance, and is drained out — repeatedly, into
/// the same reused slot.  Asserts no stall, no cross-model lane leakage
/// (every output bit-identical to its solo reference), the registry and
/// per-model metrics returning to the base state after each unload, and
/// load/unload counters.
#[test]
fn registry_churn_under_saturation() {
    let lanes = 2usize;
    let qam_a = common::random_model_seeded(2, 16, Some(8), 0xA0A0);
    let model_a = Arc::new(AcousticModel::from_qam(&qam_a, ExecMode::Quant).unwrap());
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let eng = Arc::new(Engine::start(model_a.clone(), decoder, sched_config(lanes, 3, 32)));

    let bulk_frames = 300usize;
    let bulk_content: Vec<Vec<f32>> =
        (0..2 * lanes).map(|s| frames(bulk_frames, 2200 + s as u64)).collect();
    let bulk_want: Vec<Vec<u32>> =
        bulk_content.iter().map(|f| greedy_ref(&model_a, f, bulk_frames)).collect();

    let churn_rounds = 5u64;
    std::thread::scope(|scope| {
        // 2× oversubscription on model A: producers block on backpressure
        // so every stream stays never-idle until fully consumed.
        let mut bulk_rx = Vec::new();
        for (s, content) in bulk_content.iter().enumerate() {
            let (id, rx) = eng
                .try_open_stream(StreamOptions { model: 0, priority: Priority::Bulk })
                .expect("bulk admission");
            bulk_rx.push((rx, s));
            let eng = eng.clone();
            scope.spawn(move || {
                eng.push_frames(id, content).unwrap();
                eng.finish_stream(id).unwrap();
            });
        }
        std::thread::sleep(Duration::from_millis(50));
        // Churn: load model B, serve one interactive utterance on it,
        // drain it out; the freed slot must be reused every round.
        let churn_frames = 8usize;
        for round in 0..churn_rounds {
            let qam_b = common::random_model_seeded(2, 12, Some(6), 0xB000 + round);
            let model_b = Arc::new(AcousticModel::from_qam(&qam_b, ExecMode::Quant).unwrap());
            let f = frames(churn_frames, 3000 + round);
            let want = greedy_ref(&model_b, &f, churn_frames);
            let id_b = eng
                .load_model_named(
                    format!("b{round}"),
                    model_b,
                    ModelParams { weight: 2, lanes: Some(1) },
                )
                .expect("hot load");
            assert_eq!(id_b, 1, "freed slot must be reused");
            {
                let reg = eng.registry();
                assert_eq!(reg.len(), 2, "{reg:?}");
                let b = reg.iter().find(|m| m.id == 1).unwrap();
                assert_eq!((b.weight, b.lanes, b.draining), (2, 1, false));
            }
            let (sid, rx) = eng
                .try_open_stream(StreamOptions { model: id_b, priority: Priority::Interactive })
                .expect("churn admission");
            eng.push_frames(sid, &f).unwrap();
            eng.finish_stream(sid).unwrap();
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap_or_else(|_| {
                panic!("round {round}: churn stream stalled under saturation")
            });
            assert_eq!(r.num_frames, churn_frames);
            assert_eq!(r.phones, want, "round {round}: churn changed numerics");
            eng.unload_model(id_b).expect("unload");
            let reg = eng.registry();
            assert_eq!(reg.len(), 1, "only the base model should remain: {reg:?}");
            assert_eq!(reg[0].id, 0);
        }
        // The saturated base-model streams must drain bit-exactly: any
        // cross-model lane leakage during churn shows up here.
        for (rx, s) in bulk_rx {
            let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(r.num_frames, bulk_frames);
            assert_eq!(r.phones, bulk_want[s], "churn leaked into model-A lanes");
        }
    });
    assert_eq!(*eng.metrics().sched_stalls.lock().unwrap(), 0);
    assert_eq!(*eng.metrics().model_loads.lock().unwrap(), 1 + churn_rounds);
    assert_eq!(*eng.metrics().model_unloads.lock().unwrap(), churn_rounds);
    let pm = eng.metrics().per_model.lock().unwrap();
    assert!(pm[0].loaded);
    assert!(!pm[1].loaded, "churn slot still loaded after unload");
    drop(pm);
    // The drained slot holds no lanes or streams: a fresh load reuses it.
    let reg = eng.registry();
    assert_eq!(reg.len(), 1);
    assert_eq!(reg[0].live_streams, 0);
}

/// Unload semantics: a draining model rejects newcomers with a reason
/// while its survivor finishes bit-exactly; after the drain the slot is
/// unknown; unloading a missing model errors.
#[test]
fn draining_model_rejects_newcomers_then_unloads() {
    let qam = common::random_model(2, 16, Some(8));
    let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let eng = Arc::new(Engine::start(model, decoder, sched_config(2, 4, 32)));

    let qam_b = common::random_model_seeded(2, 12, Some(6), 0xDAB);
    let model_b = Arc::new(AcousticModel::from_qam(&qam_b, ExecMode::Quant).unwrap());
    let n = 4usize;
    let f = frames(n, 42);
    let want = greedy_ref(&model_b, &f, n);
    let id_b = eng.load_model(model_b, ModelParams::default()).unwrap();
    assert_eq!(id_b, 1);
    // A live, unfinished stream keeps the model draining (not torn down).
    let (sid, rx) = eng
        .try_open_stream(StreamOptions { model: id_b, priority: Priority::Interactive })
        .unwrap();
    eng.push_frames(sid, &f).unwrap();
    let eng2 = eng.clone();
    let unloader = std::thread::spawn(move || eng2.unload_model(id_b));
    // The draining flag is set synchronously by unload_model; wait for
    // the spawned thread to have taken the lock.
    let mut draining_seen = false;
    for _ in 0..400 {
        if eng.registry().iter().any(|m| m.id == id_b && m.draining) {
            draining_seen = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(draining_seen, "unload never marked the model draining");
    match eng.try_open_stream(StreamOptions { model: id_b, ..Default::default() }) {
        Err(RejectReason::ModelDraining { model }) => assert_eq!(model, id_b),
        other => panic!("expected draining reject, got {other:?}"),
    }
    // The survivor finishes normally and bit-exactly; then the unload
    // completes and the slot reads as unknown.
    eng.finish_stream(sid).unwrap();
    let r = rx.recv_timeout(Duration::from_secs(20)).unwrap();
    assert_eq!(r.num_frames, n);
    assert_eq!(r.phones, want, "drain changed survivor numerics");
    let unload_result = unloader.join().unwrap();
    unload_result.expect("unload completes after the drain");
    match eng.try_open_stream(StreamOptions { model: id_b, ..Default::default() }) {
        Err(RejectReason::UnknownModel { model, loaded }) => {
            assert_eq!((model, loaded), (id_b, 1));
        }
        other => panic!("expected unknown-model reject, got {other:?}"),
    }
    assert!(eng.unload_model(9).is_err());
    assert!(eng.unload_model(id_b).is_err(), "double unload must error");
}

/// Weighted fairness end to end: two saturated models with weights 3:1
/// split the tick budget ≈3:1 (measured over a sampling window; the
/// exact convergence property is unit-tested in sched::weights — this
/// checks the engine actually applies the grant).
#[test]
fn weighted_shares_track_configured_ratios_under_saturation() {
    let qam_a = common::random_model_seeded(2, 16, Some(8), 0x3AAA);
    let qam_b = common::random_model_seeded(2, 16, Some(8), 0x3BBB);
    let model_a = Arc::new(AcousticModel::from_qam(&qam_a, ExecMode::Quant).unwrap());
    let model_b = Arc::new(AcousticModel::from_qam(&qam_b, ExecMode::Quant).unwrap());
    let mut registry = ModelRegistry::new();
    registry.register_named("heavy", model_a);
    registry.register_named("light", model_b);
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let mut cfg = sched_config(4, 8, 64);
    cfg.model_weights = vec![3, 1];
    let eng = Arc::new(Engine::start_registry(registry, decoder, cfg));

    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        // 4 never-idle bulk streams per model: each model's demand fills
        // its lanes every tick, so the 4-step budget is contended 2×.
        for m in 0..2usize {
            for s in 0..4usize {
                let eng = eng.clone();
                let stop = stop.clone();
                scope.spawn(move || {
                    let chunk = frames(16, (9000 + m * 100 + s) as u64);
                    let (id, rx) = eng
                        .try_open_stream(StreamOptions { model: m, priority: Priority::Bulk })
                        .expect("admission");
                    while !stop.load(Ordering::SeqCst) {
                        eng.push_frames(id, &chunk).unwrap();
                    }
                    eng.finish_stream(id).unwrap();
                    let _ = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                });
            }
        }
        // Warm up, then measure a window.
        std::thread::sleep(Duration::from_millis(300));
        let (a0, b0) = {
            let pm = eng.metrics().per_model.lock().unwrap();
            (pm[0].frames, pm[1].frames)
        };
        std::thread::sleep(Duration::from_millis(1200));
        let (a1, b1) = {
            let pm = eng.metrics().per_model.lock().unwrap();
            (pm[0].frames, pm[1].frames)
        };
        stop.store(true, Ordering::SeqCst);
        let (da, db) = ((a1 - a0) as f64, (b1 - b0).max(1) as f64);
        let ratio = da / db;
        assert!(
            ratio > 1.8 && ratio < 5.0,
            "weighted share off: {da}/{db} = {ratio:.2} (want ≈3)"
        );
    });
    // The budget actually bound: the light model deferred planned steps.
    let pm = eng.metrics().per_model.lock().unwrap();
    assert!(pm[1].deferrals > 0, "the tick budget never bound");
    drop(pm);
    assert_eq!(*eng.metrics().sched_stalls.lock().unwrap(), 0);
}

/// The TCP admin protocol: 'Q' registry snapshots, 'L' hot load through
/// the server's loader, 'M' model selection for streams, 'U' drain +
/// unload, and admin failures as 'R' frames that keep the connection
/// usable.  A loader-less server rejects 'L' with a reason.
#[test]
fn tcp_admin_load_query_unload() {
    let qam = common::random_model(2, 16, Some(8));
    let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let engine = Arc::new(Engine::start(model, decoder.clone(), sched_config(2, 4, 32)));
    // Loader: synthesizes a model per "path" (tests run without artifact
    // files; the production loader maps paths to .qam loads).
    let loader: ModelLoader<AcousticModel> = Arc::new(|spec: &str| {
        anyhow::ensure!(spec != "missing.qam", "no such model: {spec}");
        let qam = common::random_model_seeded(2, 12, Some(6), 0xC0FFEE);
        Ok(Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant)?))
    });

    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv_engine = engine.clone();
    let srv_stop = stop.clone();
    let server = std::thread::spawn(move || {
        serve_with_loader(srv_engine, "127.0.0.1:0", srv_stop, Some(loader), move |a| {
            let _ = addr_tx.send(a);
        })
        .expect("server failed");
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(10)).unwrap().to_string();

    let mut admin = Client::connect(&addr).unwrap();
    let reg = admin.query_registry().unwrap();
    assert_eq!(reg.len(), 1);
    assert_eq!(reg[0].id, 0);
    assert!(!reg[0].draining);
    // Hot load with weight 2, 1 lane; the loader can also fail -> 'R'.
    assert!(admin.load_model("missing.qam", 1, 0).is_err());
    let id = admin.load_model("synthetic-b.qam", 2, 1).unwrap();
    assert_eq!(id, 1);
    let reg = admin.query_registry().unwrap();
    assert_eq!(reg.len(), 2);
    let b = reg.iter().find(|e| e.id == 1).expect("hot-loaded row");
    assert_eq!((b.weight, b.lanes, b.live_streams), (2, 1, 0));
    // Serve one utterance on the hot-loaded model over TCP ('M' frame).
    let mut c = Client::connect(&addr).unwrap();
    c.set_model(1).unwrap();
    c.set_priority(Priority::Interactive).unwrap();
    c.send_audio(&[0.01f32; 1600]).unwrap();
    let r = c.finish().expect("stream on the hot-loaded model");
    assert!(r.server_latency_ms >= 0.0);
    // Drain + unload over TCP; new streams to the slot reject with the
    // unknown-model reason.
    admin.unload_model(1).unwrap();
    let reg = admin.query_registry().unwrap();
    assert_eq!(reg.len(), 1);
    let mut c2 = Client::connect(&addr).unwrap();
    c2.set_model(1).unwrap();
    c2.send_audio(&[0.01f32; 800]).unwrap();
    let err = c2.finish().expect_err("stream on the unloaded model must reject");
    assert!(format!("{err:#}").contains("unknown model"), "{err:#}");
    // Admin failures keep the connection usable.
    assert!(admin.unload_model(7).is_err());
    assert_eq!(admin.query_registry().unwrap().len(), 1);
    stop.store(true, Ordering::SeqCst);
    drop(admin); // the conn thread exits when the socket closes
    server.join().unwrap();

    // A loader-less server ('serve') rejects 'L' with a reason but keeps
    // 'U'/'Q' admin and normal streaming intact.
    let stop2 = Arc::new(AtomicBool::new(false));
    let (addr_tx2, addr_rx2) = std::sync::mpsc::channel();
    let srv_engine2 = engine.clone();
    let srv_stop2 = stop2.clone();
    let server2 = std::thread::spawn(move || {
        serve(srv_engine2, "127.0.0.1:0", srv_stop2, move |a| {
            let _ = addr_tx2.send(a);
        })
        .expect("server failed");
    });
    let addr2 = addr_rx2.recv_timeout(Duration::from_secs(10)).unwrap().to_string();
    let mut admin2 = Client::connect(&addr2).unwrap();
    let err = admin2.load_model("x.qam", 1, 0).expect_err("no loader configured");
    assert!(format!("{err:#}").contains("loader"), "{err:#}");
    assert_eq!(admin2.query_registry().unwrap().len(), 1);
    stop2.store(true, Ordering::SeqCst);
    drop(admin2);
    server2.join().unwrap();
}

/// Byte-budget conservation property: a 5000-op randomized churn of
/// loads, unloads, admissions, parks, unparks, and drains keeps the
/// ledger's resident count equal to a shadow model's at every step,
/// never past the budget, with per-model `parked ≤ reserved`; a full
/// drain at the end returns every byte.
#[test]
fn budget_ledger_conserves_bytes_under_randomized_churn() {
    let budget = 10_000usize;
    let mut ledger = BudgetLedger::new(Some(budget));
    let mut rng = Xoshiro256::new(0xB1D6E7);
    // Shadow per model: (arena bytes if loaded, per-stream blob size,
    // one parked flag per live stream).
    let mut shadow: Vec<(Option<usize>, usize, Vec<bool>)> =
        (0..4usize).map(|m| (None, 64 * (m + 1), Vec::new())).collect();
    for step in 0..5000 {
        let m = (rng.next_u64() % 4) as usize;
        let blob = shadow[m].1;
        match rng.next_u64() % 6 {
            0 => {
                if shadow[m].0.is_none() {
                    let bytes = 256 * (m + 1);
                    if ledger.fits(bytes) {
                        ledger.charge_arena(m, bytes);
                        shadow[m].0 = Some(bytes);
                    }
                }
            }
            1 => {
                // Teardown only happens with no reservations outstanding
                // (the engine drains streams before releasing the arena).
                if shadow[m].0.is_some() && shadow[m].2.is_empty() {
                    ledger.release_arena(m);
                    shadow[m].0 = None;
                }
            }
            2 => {
                if shadow[m].0.is_some() && ledger.fits(blob) {
                    ledger.charge_stream(m, blob);
                    shadow[m].2.push(false);
                }
            }
            3 => {
                if let Some(i) = shadow[m].2.iter().position(|p| !*p) {
                    ledger.note_parked(m, blob);
                    shadow[m].2[i] = true;
                }
            }
            4 => {
                if let Some(i) = shadow[m].2.iter().position(|p| *p) {
                    ledger.note_unparked(m, blob);
                    shadow[m].2[i] = false;
                }
            }
            _ => {
                if let Some(was_parked) = shadow[m].2.pop() {
                    ledger.release_stream(m, blob, was_parked);
                }
            }
        }
        let want: usize =
            shadow.iter().map(|(a, b, ss)| a.unwrap_or(0) + b * ss.len()).sum();
        assert_eq!(ledger.resident(), want, "step {step}: bytes leaked or double-counted");
        assert!(ledger.resident() <= budget, "step {step}: ledger past its budget");
        for (mm, (_, b, ss)) in shadow.iter().enumerate() {
            let row = ledger.model(mm);
            assert_eq!(row.reserved, b * ss.len(), "step {step} model {mm}: reserved");
            assert_eq!(
                row.parked,
                b * ss.iter().filter(|p| **p).count(),
                "step {step} model {mm}: parked"
            );
            assert!(row.parked <= row.reserved, "step {step}: parked past reserved");
        }
    }
    // Full drain: every byte comes back and the ledger reads empty.
    for m in 0..4usize {
        let blob = shadow[m].1;
        while let Some(was_parked) = shadow[m].2.pop() {
            ledger.release_stream(m, blob, was_parked);
        }
        if shadow[m].0.take().is_some() {
            ledger.release_arena(m);
        }
    }
    assert_eq!(ledger.resident(), 0, "drained ledger still holds bytes");
    assert_eq!(ledger.parked(), 0);
    assert!(ledger.is_empty());
}

/// The byte budget backpressures end to end: admission charges one
/// parked blob per stream against `--mem-budget-bytes`, rejects with the
/// machine-readable memory-pressure reason at the cap, surfaces the
/// ledger in the registry rows and the metrics report, refuses a model
/// load whose arena cannot fit, and returns the full reservation when
/// the streams drain.
#[test]
fn engine_budget_backpressures_and_recovers() {
    let qam = common::random_model(2, 16, Some(8));
    let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
    let blob = model.lane_state_bytes();
    let arena = model.arena_bytes(2);
    assert!(blob > 0 && arena > 0);
    let budget = arena + 2 * blob;
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let mut cfg = sched_config(2, 4, 32);
    cfg.mem_budget = Some(budget);
    let eng = Engine::start(model.clone(), decoder, cfg);

    let info = eng.overload_info();
    assert_eq!(info.budget_bytes, budget);
    assert_eq!(info.resident_bytes, arena, "boot charges the arena only");

    // Two admissions fill the budget; the third backpressures.
    let (id_a, rx_a) = eng.try_open_stream(StreamOptions::default()).unwrap();
    let (id_b, rx_b) = eng.try_open_stream(StreamOptions::default()).unwrap();
    match eng.try_open_stream(StreamOptions::default()) {
        Err(RejectReason::MemoryPressure { resident, budget: b }) => {
            assert_eq!((resident, b), (arena + 2 * blob, budget));
        }
        other => panic!("expected memory-pressure reject, got {other:?}"),
    }
    assert_eq!(*eng.metrics().mem_pressure_rejects.lock().unwrap(), 1);
    // The ledger is visible: registry row and report agree with it.
    let reg = eng.registry();
    assert_eq!(reg[0].arena_bytes, arena);
    assert_eq!(reg[0].reserved_bytes, 2 * blob);
    let report = eng.metrics().report();
    assert!(report.contains(&format!("resident_bytes={}", arena + 2 * blob)), "{report}");
    assert!(report.contains(&format!("budget_bytes={budget}")), "{report}");
    // A model whose arena cannot fit the remaining budget is refused.
    let qam_b = common::random_model_seeded(2, 16, Some(8), 0xFEE1);
    let model_b = Arc::new(AcousticModel::from_qam(&qam_b, ExecMode::Quant).unwrap());
    assert!(model_b.arena_bytes(4) + arena + 2 * blob > budget, "test sizing precondition");
    let err = eng
        .load_model(model_b, ModelParams { weight: 1, lanes: Some(4) })
        .expect_err("an over-budget load must be refused");
    assert!(err.contains("memory pressure"), "{err}");

    // Drain both streams bit-exactly; the reservations come back.
    let n = 6usize;
    for (i, (id, rx)) in [(id_a, rx_a), (id_b, rx_b)].into_iter().enumerate() {
        let f = frames(n, 0xEB0 + i as u64);
        let want = greedy_ref(&model, &f, n);
        eng.push_frames(id, &f).unwrap();
        eng.finish_stream(id).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(20)).unwrap();
        assert_eq!(r.phones, want, "budgeted stream {i} numerics");
    }
    let deadline = Instant::now() + Duration::from_secs(5);
    while eng.overload_info().resident_bytes != arena {
        assert!(Instant::now() < deadline, "stream reservations never released");
        std::thread::sleep(Duration::from_millis(10));
    }
    // Capacity is back: a fresh admission succeeds.
    let (id_c, _rx_c) = eng.try_open_stream(StreamOptions::default()).expect("recovered");
    let _ = eng.finish_stream(id_c);
}

/// The TCP overload-admin surface: 'T' serves the Prometheus exposition,
/// 'Q' carries the overload header and per-model byte columns, and 'S'
/// swaps a model with zero downtime — the survivor on the old model
/// finishes normally while a newcomer still dialing the old id is
/// redirected to the replacement.  A loader-less server rejects 'S' with
/// a reason.
#[test]
fn tcp_swap_metrics_and_snapshot() {
    let qam = common::random_model(2, 16, Some(8));
    let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let engine = Arc::new(Engine::start(model.clone(), decoder, sched_config(2, 4, 32)));
    let loader: ModelLoader<AcousticModel> = Arc::new(|spec: &str| {
        anyhow::ensure!(spec != "missing.qam", "no such model: {spec}");
        let qam = common::random_model_seeded(2, 12, Some(6), 0x5A4B);
        Ok(Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant)?))
    });

    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv_engine = engine.clone();
    let srv_stop = stop.clone();
    let server = std::thread::spawn(move || {
        serve_with_loader(srv_engine, "127.0.0.1:0", srv_stop, Some(loader), move |a| {
            let _ = addr_tx.send(a);
        })
        .expect("server failed");
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(10)).unwrap().to_string();

    let mut admin = Client::connect(&addr).unwrap();
    // 'T': well-formed Prometheus exposition over the wire.
    let text = admin.metrics_text().unwrap();
    assert!(text.contains("# HELP quantasr_model_swaps_total"), "{text}");
    assert!(text.contains("quantasr_resident_bytes"), "{text}");
    // 'Q': overload header plus byte columns.
    let snap = admin.query_snapshot().unwrap();
    assert_eq!(snap.brownout_stage, 0);
    assert_eq!(snap.budget_bytes, 0, "no budget configured");
    assert!(snap.resident_bytes > 0, "the boot arena is resident");
    assert_eq!(snap.models.len(), 1);
    assert!(snap.models[0].arena_bytes > 0);
    assert_eq!(snap.models[0].reserved_bytes, 0);

    // A survivor holds a live stream on model 0 across the swap.
    let mut survivor = Client::connect(&addr).unwrap();
    survivor.set_model(0).unwrap();
    survivor.send_audio(&[0.01f32; 1600]).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let reg = admin.query_registry().unwrap();
        if reg.iter().any(|e| e.id == 0 && e.live_streams == 1) {
            break;
        }
        assert!(Instant::now() < deadline, "survivor never reached the engine");
        std::thread::sleep(Duration::from_millis(20));
    }
    // 'S': canaried swap; the replacement takes slot 1.
    let new_id = admin.swap_model(0, "replacement.qam", 1, 2).expect("swap over TCP");
    assert_eq!(new_id, 1);
    let reg = admin.query_registry().unwrap();
    let old = reg.iter().find(|e| e.id == 0).expect("old row while draining");
    assert!(old.draining, "the swapped-out model drains");
    // A newcomer still dialing the old id is served by the replacement.
    let mut redirected = Client::connect(&addr).unwrap();
    redirected.set_model(0).unwrap();
    redirected.send_audio(&[0.01f32; 1600]).unwrap();
    let r = redirected.finish().expect("newcomer redirected to the replacement");
    assert!(r.server_latency_ms >= 0.0);
    // The survivor finishes normally on the old model.
    let r = survivor.finish().expect("survivor drains on the old model");
    assert!(r.server_latency_ms >= 0.0);
    // The old slot tears down once drained; the swap counter ticks.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let reg = admin.query_registry().unwrap();
        if reg.len() == 1 && reg[0].id == 1 {
            break;
        }
        assert!(Instant::now() < deadline, "old slot never tore down: {reg:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    let text = admin.metrics_text().unwrap();
    assert!(text.contains("quantasr_model_swaps_total 1"), "{text}");
    assert!(text.contains("quantasr_swap_rollbacks_total 0"), "{text}");
    stop.store(true, Ordering::SeqCst);
    drop(admin);
    server.join().unwrap();

    // A loader-less server rejects 'S' with a reason and stays usable.
    let stop2 = Arc::new(AtomicBool::new(false));
    let (addr_tx2, addr_rx2) = std::sync::mpsc::channel();
    let srv_engine2 = engine.clone();
    let srv_stop2 = stop2.clone();
    let server2 = std::thread::spawn(move || {
        serve(srv_engine2, "127.0.0.1:0", srv_stop2, move |a| {
            let _ = addr_tx2.send(a);
        })
        .expect("server failed");
    });
    let addr2 = addr_rx2.recv_timeout(Duration::from_secs(10)).unwrap().to_string();
    let mut admin2 = Client::connect(&addr2).unwrap();
    let err = admin2.swap_model(1, "x.qam", 1, 0).expect_err("no loader configured");
    assert!(format!("{err:#}").contains("loader"), "{err:#}");
    assert!(!admin2.metrics_text().unwrap().is_empty());
    stop2.store(true, Ordering::SeqCst);
    drop(admin2);
    server2.join().unwrap();
}

/// In-situ requantization on the serving plane: a per-matrix-u8 model and
/// a per-channel-i4 model share one engine, oversubscribed so quantum
/// preemption parks and restores int4-lane state mid-utterance — every
/// stream must stay bit-identical to its unpreempted solo run, and the
/// registry must report each model's scheme.  Then a canaried
/// [`Engine::swap_model`] replaces the u8 model with an i4 build of the
/// same weights: a live survivor drains bit-exactly on the old numerics
/// while newcomers dialing the old id are served by the i4 replacement.
#[test]
fn mixed_scheme_models_serve_concurrently_and_swap_u8_to_i4() {
    let qam_a = common::random_model_seeded(2, 16, Some(8), 0x15_0A8);
    let qam_b = common::random_model_seeded(2, 12, Some(6), 0x15_0B4);
    let model_a = Arc::new(
        AcousticModel::from_qam_scheme(&qam_a, ExecMode::Quant, QuantScheme::PerMatrixU8).unwrap(),
    );
    let model_b = Arc::new(
        AcousticModel::from_qam_scheme(&qam_b, ExecMode::Quant, QuantScheme::PerChannelI4)
            .unwrap(),
    );
    let mut registry = ModelRegistry::new();
    assert_eq!(registry.register_named("pm-u8", model_a.clone()), 0);
    assert_eq!(registry.register_named("pc-i4", model_b.clone()), 1);
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    // 2 lanes for 6 streams with a short quantum: both schemes get parked
    // and restored repeatedly while the other model holds the lane.
    let eng = Engine::start_registry(registry, decoder, sched_config(2, 3, 32));

    let reg = eng.registry();
    assert_eq!(reg.len(), 2);
    assert_eq!(reg[0].scheme, "per-matrix-u8");
    assert_eq!(reg[1].scheme, "per-channel-i4");

    let per_model_streams = 3usize;
    let total = 15usize;
    let mut rxs = Vec::new();
    for s in 0..per_model_streams {
        for (midx, model) in [(0usize, &model_a), (1usize, &model_b)] {
            let f = frames(total, 0x9100 + (midx * 100 + s) as u64);
            let want = greedy_ref(model, &f, total);
            let (id, rx) = eng
                .try_open_stream(StreamOptions { model: midx, priority: Priority::Interactive })
                .expect("admission");
            eng.push_frames(id, &f).unwrap();
            eng.finish_stream(id).unwrap();
            rxs.push((rx, midx, want));
        }
    }
    for (rx, midx, want) in rxs {
        let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r.num_frames, total);
        assert_eq!(r.phones, want, "model {midx}: mixed-scheme serving changed numerics");
    }
    assert!(
        *eng.metrics().preemptions.lock().unwrap() >= 1,
        "6 streams on 2 lanes with quantum 3 must preempt (park/restore exercised)"
    );

    // Canaried swap u8 → i4 on the same weights.  The survivor keeps its
    // stream open across the swap and must finish on the old u8 numerics.
    let model_a_i4 = Arc::new(
        AcousticModel::from_qam_scheme(&qam_a, ExecMode::Quant, QuantScheme::PerChannelI4)
            .unwrap(),
    );
    let n = 15usize;
    let f = frames(n, 0x51_7E);
    let want_u8 = greedy_ref(&model_a, &f, n);
    let want_i4 = greedy_ref(&model_a_i4, &f, n);
    let (sid, survivor_rx) = eng
        .try_open_stream(StreamOptions { model: 0, priority: Priority::Interactive })
        .expect("survivor admission");
    eng.push_frames(sid, &f).unwrap();
    let new_id = eng
        .swap_model(0, model_a_i4, ModelParams { weight: 1, lanes: Some(1) })
        .expect("canaried u8→i4 swap");
    // A newcomer still dialing the old id is redirected to the i4
    // replacement and gets its numerics, not the old u8 ones.
    let (nid, newcomer_rx) = eng
        .try_open_stream(StreamOptions { model: 0, priority: Priority::Interactive })
        .expect("redirected admission");
    eng.push_frames(nid, &f).unwrap();
    eng.finish_stream(nid).unwrap();
    let r = newcomer_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(r.phones, want_i4, "redirected stream not served by the i4 replacement");
    // The survivor drains bit-exactly on the swapped-out u8 weights.
    eng.finish_stream(sid).unwrap();
    let r = survivor_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(r.phones, want_u8, "swap changed the survivor's u8 numerics");
    // Old slot tears down once drained; the replacement row carries the
    // i4 scheme tag.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let reg = eng.registry();
        let done = !reg.iter().any(|m| m.id == 0)
            && reg.iter().any(|m| m.id == new_id && m.scheme == "per-channel-i4" && !m.draining);
        if done {
            break;
        }
        assert!(Instant::now() < deadline, "old u8 slot never tore down: {reg:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
}
