//! Integration tests against the real build artifacts (`make artifacts`):
//! trained models, datasets, and the AOT/PJRT bridge.  All tests skip
//! gracefully when artifacts/ is absent.

mod common;

use quantasr::decoder::DecoderConfig;
use quantasr::eval::{build_decoder, evaluate};
use quantasr::io::feat_fmt::read_feats;
use quantasr::nn::{AcousticModel, ExecMode};
use quantasr::sim::World;

#[test]
fn trained_model_beats_chance_by_a_lot() {
    let Some(art) = common::artifacts() else { return };
    let utts = read_feats(art.join("data/eval_clean.feats")).unwrap();
    let model =
        AcousticModel::load(art.join("models/p24.qat.qam"), ExecMode::Quant).unwrap();
    let decoder = build_decoder(&World::new(), DecoderConfig::default());
    let r = evaluate(&model, &decoder, &utts[..64.min(utts.len())], 4);
    assert!(r.ler < 0.5, "LER {} — model did not learn", r.ler);
    assert!(r.wer < 0.5, "WER {} — decoding broken", r.wer);
}

#[test]
fn exec_modes_agree_on_trained_model() {
    // The quantized path must track the float path closely on real data
    // (that is the entire point of the paper).
    let Some(art) = common::artifacts() else { return };
    let utts = read_feats(art.join("data/eval_clean.feats")).unwrap();
    let qam = quantasr::io::model_fmt::QamFile::load(art.join("models/p24.float.qam")).unwrap();
    let mf = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
    let mq = AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap();
    let u = &utts[0];
    let lf = mf.forward_utt(&u.feats, u.num_frames);
    let lq = mq.forward_utt(&u.feats, u.num_frames);
    // compare greedy decisions, not raw floats (quantization shifts both)
    let gf = quantasr::decoder::ctc::greedy(&lf, mf.num_labels());
    let gq = quantasr::decoder::ctc::greedy(&lq, mq.num_labels());
    let dist = quantasr::decoder::wer::edit_distance(&gf, &gq);
    assert!(
        dist <= 1 + gf.len() / 5,
        "quantized path diverged: {gf:?} vs {gq:?}"
    );
}

#[test]
fn python_dataset_readable_and_consistent() {
    let Some(art) = common::artifacts() else { return };
    for split in ["eval_clean", "eval_noisy", "dev"] {
        let utts = read_feats(art.join(format!("data/{split}.feats"))).unwrap();
        assert!(!utts.is_empty());
        for u in utts.iter().take(50) {
            assert_eq!(u.feats.len(), u.num_frames * u.dim);
            assert_eq!(u.dim, quantasr::frontend::spec::FEAT_DIM);
            assert_eq!(u.align.len(), u.num_frames);
            assert!(u.phones.iter().all(|&p| (1..=40).contains(&p)));
            assert!(u.words.iter().all(|&w| w < 200));
            assert!(u.feats.iter().all(|v| v.is_finite()));
        }
    }
}

#[test]
fn clean_and_noisy_eval_share_references() {
    let Some(art) = common::artifacts() else { return };
    let clean = read_feats(art.join("data/eval_clean.feats")).unwrap();
    let noisy = read_feats(art.join("data/eval_noisy.feats")).unwrap();
    assert_eq!(clean.len(), noisy.len());
    for (c, n) in clean.iter().zip(&noisy).take(100) {
        assert_eq!(c.words, n.words, "same seed ⇒ same content");
        assert_eq!(c.phones, n.phones);
    }
}

#[test]
fn qam_files_load_with_expected_flags() {
    let Some(art) = common::artifacts() else { return };
    use quantasr::io::model_fmt::QamFile;
    let f = QamFile::load(art.join("models/p24.float.qam")).unwrap();
    assert!(!f.header.quantized);
    let q = QamFile::load(art.join("models/p24.qat.qam")).unwrap();
    assert!(q.header.quantized && !q.header.quantize_output);
    let qa = QamFile::load(art.join("models/p24.qatall.qam")).unwrap();
    assert!(qa.header.quantized && qa.header.quantize_output);
    // quantized files are much smaller (the paper's memory claim)
    assert!(q.storage_bytes() * 3 < f.storage_bytes());
}

#[cfg(feature = "pjrt")]
#[test]
fn native_matches_pjrt_artifacts() {
    // The handwritten int8 engine and the AOT JAX graph (with the stored u8
    // weights baked in) must agree numerically.
    let Some(art) = common::artifacts() else { return };
    if !art.join("hlo/p24.quant.b1.hlo.txt").exists() {
        eprintln!("SKIPPED: hlo artifacts missing");
        return;
    }
    let utts = read_feats(art.join("data/eval_clean.feats")).unwrap();
    let u = &utts[0];
    let rt = quantasr::runtime::Runtime::cpu().unwrap();
    for (variant, qam, mode, tol) in [
        ("float", "p24.float.qam", ExecMode::Float, 2e-3f32),
        ("quant", "p24.qat.qam", ExecMode::Quant, 2e-3),
    ] {
        let exe = rt.load_model(art.join(format!("hlo/p24.{variant}.b1"))).unwrap();
        let pjrt = exe.forward_utt(&u.feats, u.num_frames).unwrap();
        let native = AcousticModel::load(art.join("models").join(qam), mode).unwrap();
        let nat = native.forward_utt(&u.feats, u.num_frames);
        let max = pjrt
            .iter()
            .zip(&nat)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max < tol, "{variant}: native vs pjrt max err {max}");
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pallas_variant_matches_jnp_variant() {
    // The AOT graph whose matmuls lower through the Pallas kernel must be
    // numerically identical to the plain-jnp quant graph.
    let Some(art) = common::artifacts() else { return };
    if !art.join("hlo/p24.quant_pallas.b1.hlo.txt").exists() {
        eprintln!("SKIPPED: pallas hlo missing");
        return;
    }
    let utts = read_feats(art.join("data/eval_clean.feats")).unwrap();
    let u = &utts[0];
    let t = 20.min(u.num_frames);
    let rt = quantasr::runtime::Runtime::cpu().unwrap();
    let jnp = rt.load_model(art.join("hlo/p24.quant.b1")).unwrap();
    let pal = rt.load_model(art.join("hlo/p24.quant_pallas.b1")).unwrap();
    let a = jnp.forward_utt(&u.feats[..t * u.dim], t).unwrap();
    let b = pal.forward_utt(&u.feats[..t * u.dim], t).unwrap();
    let max = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    assert!(max < 1e-4, "pallas vs jnp max err {max}");
}
