//! Shared helpers for integration tests.

use std::path::PathBuf;

/// Locate the artifacts directory (built by `make artifacts`).  Tests that
/// need trained models/golden files skip (print + return None) when it is
/// absent, so `cargo test` works on a fresh checkout too.
pub fn artifacts() -> Option<PathBuf> {
    let p = std::env::var("QUANTASR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if p.join("data/eval_clean.feats").exists() {
        Some(p)
    } else {
        eprintln!(
            "SKIPPED: artifacts not found at {} (run `make artifacts`)",
            p.display()
        );
        None
    }
}

/// Build a small random float model (same shape family as the paper grid).
pub fn random_model(
    layers: usize,
    cells: usize,
    proj: Option<usize>,
) -> quantasr::io::model_fmt::QamFile {
    random_model_seeded(layers, cells, proj, 0x7E57)
}

/// [`random_model`] with an explicit weight seed — multi-model tests need
/// models that disagree, so lane mixups are detectable in the outputs.
pub fn random_model_seeded(
    layers: usize,
    cells: usize,
    proj: Option<usize>,
    seed: u64,
) -> quantasr::io::model_fmt::QamFile {
    use quantasr::io::model_fmt::{ModelHeader, QamFile, Tensor};
    use quantasr::util::rng::Xoshiro256;
    use std::collections::BTreeMap;

    let input_dim = quantasr::frontend::spec::FEAT_DIM;
    let labels = quantasr::frontend::spec::N_LABELS;
    let rec = proj.unwrap_or(cells);
    let mut rng = Xoshiro256::new(seed);
    let mut tensors = BTreeMap::new();
    let mut mk = |name: String, i: usize, o: usize, rng: &mut Xoshiro256| {
        let scale = (1.0 / i as f64).sqrt() as f32 * 1.7;
        let mut data = vec![0f32; i * o];
        for v in data.iter_mut() {
            *v = rng.normal() as f32 * scale;
        }
        (name, Tensor::F32 { shape: vec![i, o], data })
    };
    for l in 0..layers {
        let ind = if l == 0 { input_dim } else { rec };
        let (n, t) = mk(format!("l{l}.wx"), ind, 4 * cells, &mut rng);
        tensors.insert(n, t);
        let (n, t) = mk(format!("l{l}.wh"), rec, 4 * cells, &mut rng);
        tensors.insert(n, t);
        tensors.insert(
            format!("l{l}.b"),
            Tensor::F32 { shape: vec![4 * cells], data: vec![0.0; 4 * cells] },
        );
        if let Some(p) = proj {
            let (n, t) = mk(format!("l{l}.wp"), cells, p, &mut rng);
            tensors.insert(n, t);
        }
    }
    let (n, t) = mk("out.w".into(), rec, labels, &mut rng);
    tensors.insert(n, t);
    tensors.insert("out.b".into(), Tensor::F32 { shape: vec![labels], data: vec![0.0; labels] });
    QamFile {
        header: ModelHeader {
            name: format!("rand{layers}x{cells}s{seed:x}"),
            num_layers: layers,
            cell_dim: cells,
            proj_dim: proj,
            input_dim,
            num_labels: labels,
            quantized: false,
            quantize_output: false,
            param_count: 0,
        },
        tensors,
    }
}
