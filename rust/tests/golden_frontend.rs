//! Cross-language golden test: the rust frontend must reproduce the python
//! frontend (`data.py`) on exported waveform→feature pairs.

mod common;

use quantasr::frontend;
use quantasr::io::model_fmt::read_f32_file;

#[test]
fn rust_frontend_matches_python_features() {
    let Some(art) = common::artifacts() else { return };
    let mut checked = 0;
    for i in 0..4 {
        let wav_path = art.join(format!("golden/frontend_{i}.wav.f32"));
        let feat_path = art.join(format!("golden/frontend_{i}.feat.f32"));
        if !wav_path.exists() {
            continue;
        }
        let wave = read_f32_file(&wav_path).unwrap();
        let want = read_f32_file(&feat_path).unwrap();
        let got = frontend::features(&wave);
        assert_eq!(got.len(), want.len(), "frame count mismatch on pair {i}");
        let mut max_err = 0f32;
        for (a, b) in got.iter().zip(&want) {
            max_err = max_err.max((a - b).abs());
        }
        // Tolerance: different FFT implementations + f32 accumulation order;
        // features are log-compressed so 1e-3 abs is far below any model
        // sensitivity (feature std is ~1.0).
        assert!(max_err < 1e-3, "pair {i}: max err {max_err}");
        checked += 1;
    }
    assert!(checked > 0, "no golden pairs found");
}

#[test]
fn rust_frontend_streaming_matches_python_features() {
    let Some(art) = common::artifacts() else { return };
    let wave = read_f32_file(art.join("golden/frontend_0.wav.f32")).unwrap();
    let want = read_f32_file(art.join("golden/frontend_0.feat.f32")).unwrap();
    let mut fe = frontend::Frontend::new();
    let mut got = Vec::new();
    for chunk in wave.chunks(333) {
        fe.push(chunk, &mut got);
    }
    assert_eq!(got.len(), want.len());
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 1e-3);
    }
}
