//! Chaos suite: the hardened serving plane under scripted, deterministic
//! fault schedules ([`quantasr::util::fault`]).
//!
//! Every scenario drives the real engine (and, for the wire-level ones,
//! the real TCP server) through a seeded [`FaultPlan`] and asserts the
//! robustness contract:
//!
//! - **no deadlock** — every wait in this file is bounded; a hang is a
//!   test failure, not a CI timeout;
//! - **bit-exact survivors** — streams the fault did not touch produce
//!   output identical to their solo reference run (on whatever kernel
//!   rung `QUANTASR_KERNEL` forces — the chaos CI job runs the matrix);
//! - **resources come back** — admission slots freed by the reaper,
//!   model slots freed by forced unloads and quarantines, are reusable;
//! - **metrics reconcile** — every injected fault is visible in exactly
//!   one counter (`reaped_streams` / `forced_cancels` /
//!   `quarantined_jobs`).
//!
//! The determinism test replays the same plan twice and requires the two
//! realized schedules to match line for line, then writes the schedule to
//! `CHAOS_schedule.log` (uploaded as the chaos CI artifact).  Engine
//! configs here always set [`EngineConfig::faults`] explicitly, so a
//! process-wide `QUANTASR_FAULTS` (the CI job pins one) never leaks into
//! a scenario that scripts its own plan.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use quantasr::coordinator::batcher::BatchPolicy;
use quantasr::coordinator::server::{serve_with_loader, Client, ModelLoader, ServerFrame};
use quantasr::coordinator::{Engine, EngineConfig, StreamEnd};
use quantasr::decoder::DecoderConfig;
use quantasr::eval::build_decoder;
use quantasr::frontend::spec;
use quantasr::nn::{AcousticModel, ExecMode};
use quantasr::sched::{
    AdmissionConfig, ModelParams, Priority, QuantumPolicy, RejectReason, StreamOptions,
};
use quantasr::sim::World;
use quantasr::util::fault::FaultPlan;
use quantasr::util::rng::Xoshiro256;

fn frames(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::new(seed);
    let mut v = vec![0f32; n * spec::FEAT_DIM];
    for x in v.iter_mut() {
        *x = rng.normal() as f32;
    }
    v
}

fn greedy_ref(model: &AcousticModel, f: &[f32], n: usize) -> Vec<u32> {
    let lp = model.forward_utt(f, n);
    quantasr::decoder::ctc::greedy(&lp, model.num_labels())
}

/// Engine config for chaos scenarios.  `faults` is a required argument —
/// never inherited from the process environment — so each scenario's
/// schedule is exactly the one it scripts.
fn chaos_config(
    max_batch: usize,
    faults: Option<Arc<FaultPlan>>,
    idle_ms: Option<u64>,
    deadline_ms: Option<u64>,
) -> EngineConfig {
    EngineConfig {
        policy: BatchPolicy { max_batch, deadline: Duration::from_millis(1) },
        decode_workers: 2,
        max_pending_frames: 64,
        quantum: QuantumPolicy { quantum_ticks: 4 },
        stream_idle: idle_ms.map(Duration::from_millis),
        stream_deadline: deadline_ms.map(Duration::from_millis),
        faults,
        ..EngineConfig::default()
    }
}

fn plan(spec: &str) -> Arc<FaultPlan> {
    Arc::new(FaultPlan::parse(spec).expect("test fault spec parses"))
}

fn small_engine(
    faults: Option<Arc<FaultPlan>>,
    idle_ms: Option<u64>,
    deadline_ms: Option<u64>,
) -> (Arc<AcousticModel>, Arc<Engine>) {
    let qam = common::random_model(2, 16, Some(8));
    let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let eng =
        Arc::new(Engine::start(model.clone(), decoder, chaos_config(2, faults, idle_ms, deadline_ms)));
    (model, eng)
}

/// Run one utterance synchronously on `model_id` and return its result
/// (whatever its [`StreamEnd`]).  Bounded: a missing result is a panic,
/// not a hang.
fn run_utt(
    eng: &Engine,
    model_id: usize,
    content: &[f32],
) -> quantasr::coordinator::FinalResult {
    let (id, rx) = eng
        .try_open_stream(StreamOptions { model: model_id, priority: Priority::Interactive })
        .expect("admission");
    eng.push_frames(id, content).unwrap();
    eng.finish_stream(id).unwrap();
    rx.recv_timeout(Duration::from_secs(30)).expect("utterance result within 30 s")
}

/// A silent client's stream is reaped at the idle timeout, its admission
/// slot comes back, and a full utterance then runs bit-exact on the
/// reclaimed capacity.
#[test]
fn idle_reaper_frees_silent_streams_and_their_slots() {
    let qam = common::random_model(2, 16, Some(8));
    let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let mut cfg = chaos_config(2, None, Some(150), None);
    // One admission slot total: the silent stream provably pins it.
    cfg.admission = AdmissionConfig { max_live_streams: 1 };
    let eng = Engine::start(model.clone(), decoder, cfg);

    // A stream that never sends a frame and never finishes.
    let (_silent, silent_rx) = eng.try_open_stream(StreamOptions::default()).expect("admission");
    match eng.try_open_stream(StreamOptions::default()) {
        Err(RejectReason::Saturated { live: 1, cap: 1 }) => {}
        other => panic!("the silent stream should pin the only slot, got {other:?}"),
    }
    // The reaper cancels it with an idle reason, freeing the slot.
    let r = silent_rx.recv_timeout(Duration::from_secs(10)).expect("reaped within 10 s");
    match &r.end {
        StreamEnd::Cancelled(why) => assert!(why.contains("idle"), "{why}"),
        other => panic!("want an idle cancel, got {other:?}"),
    }
    assert_eq!(*eng.metrics().reaped_streams.lock().unwrap(), 1);

    // The reclaimed slot serves a normal utterance, bit-exact.
    let n = 25usize;
    let content = frames(n, 0xA11CE);
    let want = greedy_ref(&model, &content, n);
    let r = run_utt(&eng, 0, &content);
    assert_eq!(r.end, StreamEnd::Complete);
    assert_eq!(r.phones, want, "survivor numerics changed after a reap");
    assert_eq!(*eng.metrics().reaped_streams.lock().unwrap(), 1, "no spurious reaps");
}

/// A stream that overstays the utterance deadline is cancelled even
/// while its client keeps the connection open; a stream that finishes in
/// time is untouched.
#[test]
fn utterance_deadline_reaps_overlong_streams() {
    let (model, eng) = small_engine(None, None, Some(250));

    // Finishes well inside the deadline: completes normally.
    let n = 10usize;
    let content = frames(n, 0xFA57);
    let want = greedy_ref(&model, &content, n);
    let r = run_utt(&eng, 0, &content);
    assert_eq!(r.end, StreamEnd::Complete);
    assert_eq!(r.phones, want);

    // Pushes a little audio, then never signals finish.
    let (id, rx) = eng.try_open_stream(StreamOptions::default()).expect("admission");
    eng.push_frames(id, &frames(5, 0x510)).unwrap();
    let r = rx.recv_timeout(Duration::from_secs(10)).expect("deadline reap within 10 s");
    match &r.end {
        StreamEnd::Cancelled(why) => assert!(why.contains("deadline"), "{why}"),
        other => panic!("want a deadline cancel, got {other:?}"),
    }
    assert_eq!(*eng.metrics().reaped_streams.lock().unwrap(), 1);
}

/// A never-finishing stream cannot pin an unload forever: the bounded
/// wait reports it, the forced retry cancels it within the deadline, and
/// the freed slot hot-loads a fresh model that serves bit-exact.
#[test]
fn forced_unload_is_bounded_and_the_slot_is_reusable() {
    let (_model_a, eng) = small_engine(None, None, None);
    let qam_b = common::random_model_seeded(2, 12, Some(6), 0xB0B);
    let model_b = Arc::new(AcousticModel::from_qam(&qam_b, ExecMode::Quant).unwrap());
    let id_b = eng
        .load_model(model_b, ModelParams { weight: 1, lanes: Some(2) })
        .expect("hot load");
    assert_eq!(id_b, 1);

    // A stream on model 1 that never finishes (a stalled client).
    let (sid, srx) = eng
        .try_open_stream(StreamOptions { model: id_b, priority: Priority::Interactive })
        .expect("admission");
    eng.push_frames(sid, &frames(8, 0x57A11)).unwrap();

    // Bounded, non-forced: expires with an actionable error.
    let err = eng
        .unload_model_deadline(id_b, Duration::from_millis(200), false)
        .expect_err("a live stream must hold the drain past the deadline");
    assert!(err.contains("1 live stream"), "{err}");
    assert!(err.contains("force"), "{err}");

    // Forced: completes within deadline + teardown, never hangs.
    let t0 = Instant::now();
    eng.unload_model_deadline(id_b, Duration::from_millis(200), true)
        .expect("forced unload completes");
    assert!(t0.elapsed() < Duration::from_secs(10), "forced unload took {:?}", t0.elapsed());
    let r = srx.recv_timeout(Duration::from_secs(5)).expect("survivor got its cancel");
    match &r.end {
        StreamEnd::Cancelled(why) => assert!(why.contains("forced"), "{why}"),
        other => panic!("want a forced-unload cancel, got {other:?}"),
    }
    assert_eq!(*eng.metrics().forced_cancels.lock().unwrap(), 1);
    assert_eq!(*eng.metrics().reaped_streams.lock().unwrap(), 0, "metrics reconcile");

    // The slot is reusable: reload and serve bit-exact.
    let qam_c = common::random_model_seeded(2, 12, Some(6), 0xCAFE);
    let model_c = Arc::new(AcousticModel::from_qam(&qam_c, ExecMode::Quant).unwrap());
    let id_c = eng
        .load_model(model_c.clone(), ModelParams { weight: 1, lanes: Some(2) })
        .expect("slot reuse after forced unload");
    assert_eq!(id_c, 1, "the forced-out slot is reused");
    let n = 20usize;
    let content = frames(n, 0xC0DE);
    let want = greedy_ref(&model_c, &content, n);
    let r = run_utt(&eng, id_c, &content);
    assert_eq!(r.end, StreamEnd::Complete);
    assert_eq!(r.phones, want, "reused slot numerics");
}

/// An injected decode panic fails exactly one utterance; its neighbors
/// before and after are bit-exact and the engine keeps serving.
#[test]
fn decode_panic_quarantines_one_utterance_only() {
    let p = plan("77:decode_panic@1");
    let (model, eng) = small_engine(Some(p.clone()), None, None);

    let n = 15usize;
    for i in 0..3u64 {
        let content = frames(n, 0xD0_0D + i);
        let want = greedy_ref(&model, &content, n);
        let r = run_utt(&eng, 0, &content);
        if i == 0 {
            match &r.end {
                StreamEnd::Failed(why) => assert!(why.contains("decode panicked"), "{why}"),
                other => panic!("the first decode must fail by injection, got {other:?}"),
            }
            assert!(r.words.is_empty() && r.phones.is_empty());
        } else {
            assert_eq!(r.end, StreamEnd::Complete, "utterance {i}");
            assert_eq!(r.phones, want, "survivor {i} not bit-exact after a panic");
        }
    }
    assert_eq!(*eng.metrics().quarantined_jobs.lock().unwrap(), 1);
    assert_eq!(p.schedule_log().len(), 1);
    assert!(p.schedule_log()[0].contains("decode_panic"), "{:?}", p.schedule_log());
}

/// A backend panic quarantines its model — newcomers are rejected with a
/// reason, its streams are cancelled — while the other model and the
/// engine keep serving; an unload then frees the slot for a clean reload.
#[test]
fn backend_panic_quarantines_the_model_not_the_engine() {
    // `@1#1`: fire on the first batched-step arrival, and only if it is
    // model 1 stepping.  The test keeps model 0 idle until after the
    // quarantine, so that first arrival is deterministically model 1's —
    // and the reloaded slot (arrivals 2+) can never re-trip it.
    let p = plan("9:backend_panic@1#1");
    let (model_a, eng) = small_engine(Some(p.clone()), None, None);
    let qam_b = common::random_model_seeded(2, 12, Some(6), 0xBAD);
    let model_b = Arc::new(AcousticModel::from_qam(&qam_b, ExecMode::Quant).unwrap());
    let id_b = eng
        .load_model(model_b, ModelParams { weight: 1, lanes: Some(2) })
        .expect("hot load");
    assert_eq!(id_b, 1);

    // First step of model 1 panics: its stream is cancelled, the slot is
    // quarantined.
    let (sid, srx) = eng
        .try_open_stream(StreamOptions { model: id_b, priority: Priority::Interactive })
        .expect("admission");
    eng.push_frames(sid, &frames(10, 0xEE)).unwrap();
    let r = srx.recv_timeout(Duration::from_secs(10)).expect("quarantine cancel within 10 s");
    match &r.end {
        StreamEnd::Cancelled(why) => assert!(why.contains("quarantined"), "{why}"),
        other => panic!("want a quarantine cancel, got {other:?}"),
    }
    match eng.try_open_stream(StreamOptions { model: id_b, priority: Priority::Interactive }) {
        Err(RejectReason::ModelQuarantined { model: 1 }) => {}
        other => panic!("newcomers must reject on the quarantined model, got {other:?}"),
    }
    let row = eng.registry().into_iter().find(|m| m.id == 1).expect("slot 1 registered");
    assert!(row.quarantined);
    assert!(*eng.metrics().quarantined_jobs.lock().unwrap() >= 1);
    assert!(eng.metrics().per_model.lock().unwrap()[1].quarantined);

    // Blast radius check: model 0 is untouched and bit-exact.
    let n = 20usize;
    let content = frames(n, 0xAB1E);
    let want = greedy_ref(&model_a, &content, n);
    let r = run_utt(&eng, 0, &content);
    assert_eq!(r.end, StreamEnd::Complete);
    assert_eq!(r.phones, want, "model 0 numerics after model 1's panic");

    // Unload tears the poisoned slot down; a reload reuses it cleanly.
    eng.unload_model(id_b).expect("unloading a quarantined model");
    let qam_c = common::random_model_seeded(2, 12, Some(6), 0xFEED);
    let model_c = Arc::new(AcousticModel::from_qam(&qam_c, ExecMode::Quant).unwrap());
    let id_c = eng
        .load_model(model_c.clone(), ModelParams { weight: 1, lanes: Some(2) })
        .expect("slot reuse after quarantine");
    assert_eq!(id_c, 1);
    assert!(!eng.metrics().per_model.lock().unwrap()[1].quarantined, "reused row is clean");
    let content = frames(n, 0x1DEA);
    let want = greedy_ref(&model_c, &content, n);
    let r = run_utt(&eng, id_c, &content);
    assert_eq!(r.end, StreamEnd::Complete);
    assert_eq!(r.phones, want, "reloaded slot numerics");
}

/// Stretched ticks change *when* work happens, never *what* it computes:
/// concurrent streams under a probabilistic slow-tick fault stay
/// bit-exact against their solo references.
#[test]
fn slow_ticks_never_change_results() {
    let (model, eng) = small_engine(Some(plan("11:slow_tick~0.4")), None, None);
    let n = 60usize;
    let contents: Vec<Vec<f32>> = (0..3).map(|i| frames(n, 0x700 + i as u64)).collect();
    let wants: Vec<Vec<u32>> = contents.iter().map(|c| greedy_ref(&model, c, n)).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = contents
            .iter()
            .map(|content| {
                let eng = eng.clone();
                scope.spawn(move || {
                    let (id, rx) = eng.try_open_stream(StreamOptions::default()).unwrap();
                    eng.push_frames(id, content).unwrap();
                    eng.finish_stream(id).unwrap();
                    rx.recv_timeout(Duration::from_secs(30)).expect("result under slow ticks")
                })
            })
            .collect();
        for (h, want) in handles.into_iter().zip(&wants) {
            let r = h.join().unwrap();
            assert_eq!(r.end, StreamEnd::Complete);
            assert_eq!(&r.phones, want, "slow ticks changed numerics");
        }
    });
}

/// The same seeded plan realizes the same schedule on two independent
/// engine runs — which is what makes a failing chaos run replayable from
/// its seed.  The realized schedule is written to `CHAOS_schedule.log`
/// (the chaos and overload CI jobs upload it as the run artifact), and —
/// for the default all-`decode_panic` schedule — the fault counters
/// reconcile exactly with it.  An env-pinned schedule (the CI jobs pin
/// seeds covering other points, e.g. `mem_pressure`) still must realize
/// identically on both runs; its admission-level faults surface as
/// rejects, which are a legitimate realization here, not a failure.
#[test]
fn fault_schedules_are_deterministic_and_logged() {
    let spec =
        std::env::var("QUANTASR_FAULTS").unwrap_or_else(|_| "77:decode_panic@1,decode_panic@3".into());
    let n = 12usize;
    let run = |seed_base: u64| -> (Vec<String>, u64, u64) {
        let p = plan(&spec);
        let (model, eng) = small_engine(Some(p.clone()), None, None);
        let mut completed = 0u64;
        for i in 0..4u64 {
            let content = frames(n, seed_base + i);
            let want = greedy_ref(&model, &content, n);
            // Admission itself may be the scripted fault (`mem_pressure`).
            let (id, rx) = match eng
                .try_open_stream(StreamOptions { model: 0, priority: Priority::Interactive })
            {
                Ok(s) => s,
                Err(_) => continue,
            };
            eng.push_frames(id, &content).unwrap();
            eng.finish_stream(id).unwrap();
            let r = rx.recv_timeout(Duration::from_secs(30)).expect("utterance result in 30 s");
            if r.end == StreamEnd::Complete {
                completed += 1;
                assert_eq!(r.phones, want, "surviving utterance {i}");
            }
        }
        let quarantined = *eng.metrics().quarantined_jobs.lock().unwrap();
        (p.schedule_log(), completed, quarantined)
    };
    // Same plan, same per-utterance arrival order ⇒ same realized
    // schedule.  (Input *content* differs across the two runs on purpose:
    // the schedule depends on the plan, not the audio.)
    let (log_a, completed_a, quarantined_a) = run(0x1000);
    let (log_b, _, _) = run(0x2000);
    assert_eq!(log_a, log_b, "same seed must realize the same schedule");
    // Strict reconciliation for the default schedule: every fired
    // decode_panic is one quarantined job and one non-completed
    // utterance; nothing else fired.
    let decode_only = spec
        .split_once(':')
        .is_some_and(|(_, rules)| rules.split(',').all(|r| r.starts_with("decode_panic")));
    if decode_only {
        let fired = log_a.iter().filter(|l| l.contains("decode_panic")).count() as u64;
        assert_eq!(fired, log_a.len() as u64, "only scripted points fired: {log_a:?}");
        assert_eq!(quarantined_a, fired);
        assert_eq!(completed_a, 4 - fired);
    }

    let mut artifact = format!("# QUANTASR_FAULTS={spec}\n");
    for line in &log_a {
        artifact.push_str(line);
        artifact.push('\n');
    }
    std::fs::write("CHAOS_schedule.log", artifact).expect("write schedule artifact");
}

fn spawn_server(
    eng: Arc<Engine>,
    stop: Arc<AtomicBool>,
) -> (String, std::thread::JoinHandle<()>) {
    let loader: ModelLoader<AcousticModel> = Arc::new(|spec: &str| {
        anyhow::ensure!(spec != "missing.qam", "no such model: {spec}");
        let qam = common::random_model_seeded(2, 12, Some(6), 0x7CB);
        Ok(Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant)?))
    });
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve_with_loader(eng, "127.0.0.1:0", stop, Some(loader), move |a| {
            let _ = addr_tx.send(a);
        })
        .expect("server failed");
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(10)).unwrap().to_string();
    (addr, server)
}

/// Wire-level acceptance: a stalled client holding a live stream cannot
/// pin an operator's unload.  The bounded 'D' admin frame reports the
/// survivor, the forced retry cancels it, the abandoned client reads its
/// `'C'` frame, and the freed slot hot-loads again over the same wire.
#[test]
fn tcp_stalled_client_cannot_pin_an_unload() {
    let (_model, eng) = small_engine(None, None, None);
    let stop = Arc::new(AtomicBool::new(false));
    let (addr, server) = spawn_server(eng.clone(), stop.clone());

    let mut admin = Client::connect(&addr).unwrap();
    let id_b = admin.load_model("b.qam", 1, 2).expect("hot load over TCP");
    assert_eq!(id_b, 1);

    // The stall: one audio chunk (delayed by the client_stall fault to
    // exercise that point too), then silence — never an 'E'.
    let mut stalled = Client::connect(&addr).unwrap();
    stalled.set_fault_plan(Some(plan("5:client_stall@1")));
    stalled.set_model(id_b).unwrap();
    stalled.send_audio(&[0.01f32; 1600]).unwrap();
    // Wait until the server has opened the stream (registry shows it).
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let reg = admin.query_registry().unwrap();
        if reg.iter().any(|e| e.id == 1 && e.live_streams == 1) {
            break;
        }
        assert!(Instant::now() < deadline, "stream never reached the engine");
        std::thread::sleep(Duration::from_millis(20));
    }

    // Bounded non-forced unload: expires with the survivor count.
    let err = admin
        .unload_model_deadline(id_b, Duration::from_millis(300), false)
        .expect_err("the stalled stream must hold the drain");
    let msg = format!("{err:#}");
    assert!(msg.contains("1 live stream") && msg.contains("force"), "{msg}");

    // Forced: bounded completion, slot freed, stalled client told why.
    let t0 = Instant::now();
    admin
        .unload_model_deadline(id_b, Duration::from_millis(300), true)
        .expect("forced unload over TCP");
    assert!(t0.elapsed() < Duration::from_secs(10), "forced unload took {:?}", t0.elapsed());
    match stalled.read_terminal().expect("the abandoned stream's terminal frame") {
        ServerFrame::Cancelled(why, trace) => {
            assert!(why.contains("forced"), "{why}");
            assert!(trace != 0, "engine-opened streams always carry a trace id");
        }
        other => panic!("want a 'C' cancel, got {}", other.kind()),
    }
    assert_eq!(admin.query_registry().unwrap().len(), 1);
    assert_eq!(*eng.metrics().forced_cancels.lock().unwrap(), 1);

    // The slot serves again end to end.
    let id2 = admin.load_model("b2.qam", 1, 2).expect("reload after forced unload");
    assert_eq!(id2, 1);
    let mut c = Client::connect(&addr).unwrap();
    c.set_model(id2).unwrap();
    c.send_audio(&[0.01f32; 1600]).unwrap();
    let r = c.finish().expect("stream on the reloaded slot");
    assert!(r.server_latency_ms >= 0.0);

    stop.store(true, Ordering::SeqCst);
    drop(admin);
    server.join().unwrap();
}

/// A corrupted outbound terminal frame surfaces as a clean protocol
/// error on the one client it hit; the server connection loop and every
/// later stream are unaffected.
#[test]
fn tcp_corrupt_frame_hits_one_client_and_the_server_survives() {
    let p = plan("3:corrupt_frame@1");
    let (_model, eng) = small_engine(Some(p.clone()), None, None);
    let stop = Arc::new(AtomicBool::new(false));
    let (addr, server) = spawn_server(eng, stop.clone());

    // First terminal frame is corrupted: the client sees a structured
    // parse error, not a hang and not a panic.
    let mut c1 = Client::connect(&addr).unwrap();
    c1.set_io_timeout(Some(Duration::from_secs(10))).unwrap();
    c1.send_audio(&[0.01f32; 1600]).unwrap();
    let err = c1.finish().expect_err("the corrupted frame must not parse");
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown server tag"), "{msg}");
    assert_eq!(p.schedule_log().len(), 1, "{:?}", p.schedule_log());
    assert!(p.schedule_log()[0].contains("corrupt_frame"));

    // The next stream on a fresh connection completes normally.
    let mut c2 = Client::connect(&addr).unwrap();
    c2.send_audio(&[0.01f32; 1600]).unwrap();
    let r = c2.finish().expect("the server must survive a corrupt-frame fault");
    assert!(r.server_latency_ms >= 0.0);

    stop.store(true, Ordering::SeqCst);
    server.join().unwrap();
}

/// Brownout overload control, end to end on a scripted schedule: forced
/// overruns (`overload_tick`) arm the controller, stage 1 sheds both
/// Bulk streams (the `shed:` cancel reason on their `'C'` path), stage 2
/// rejects every new admission, and a calm flush cadence recovers to
/// normal admission.  The Interactive survivor is never touched and
/// drains bit-exact; the realized fault schedule is exactly the
/// scripted one.
#[test]
fn brownout_sheds_bulk_first_then_recovers() {
    const FORCED: usize = 30;
    let rules =
        (1..=FORCED).map(|i| format!("overload_tick@{i}")).collect::<Vec<_>>().join(",");
    let p = plan(&format!("21:{rules}"));
    let qam = common::random_model(2, 16, Some(8));
    let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    // 50 ms batch deadline: calm single-stream flushes sit near ratio
    // 1.0, far under the 1.5 exit bar, so recovery cannot flap on a slow
    // machine.  The forced ticks ignore wall clock entirely.
    let mut cfg = chaos_config(2, Some(p.clone()), None, None);
    cfg.policy.deadline = Duration::from_millis(50);
    let eng = Arc::new(Engine::start(model.clone(), decoder, cfg));

    let total = 60usize;
    let fdim = spec::FEAT_DIM;
    let content = frames(total, 0xB0B0);
    let want = greedy_ref(&model, &content, total);

    // Two Bulk victims-to-be (open before any flush so the shed pass
    // sees them) and the Interactive survivor that feeds the flush clock.
    let bulk: Vec<_> = (0..2)
        .map(|_| {
            eng.try_open_stream(StreamOptions { model: 0, priority: Priority::Bulk })
                .expect("bulk admission")
        })
        .collect();
    let (sid, s_rx) = eng
        .try_open_stream(StreamOptions { model: 0, priority: Priority::Interactive })
        .expect("interactive admission");

    std::thread::scope(|scope| {
        // Bulk producers push until the stream is shed out from under
        // them — pushes to a cancelled stream error by design.
        let mut bulk_rx = Vec::new();
        for (i, (id, rx)) in bulk.into_iter().enumerate() {
            bulk_rx.push(rx);
            let eng = eng.clone();
            let chunk = frames(600, 0x600 + i as u64);
            scope.spawn(move || {
                let _ = eng.push_frames(id, &chunk);
                let _ = eng.finish_stream(id);
            });
        }
        // Forced ticks 1-2 arm the controller, tick 3 enters stage 1 and
        // sheds both Bulk streams, tick 4 finds no Bulk left and
        // escalates to rejecting admissions.
        eng.push_frames(sid, &content[..10 * fdim]).unwrap();
        for (i, rx) in bulk_rx.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(30)).expect("shed verdict within 30 s");
            match r.end {
                StreamEnd::Cancelled(why) => {
                    assert!(why.starts_with("shed:"), "bulk {i}: wrong cancel reason: {why}")
                }
                other => panic!("bulk {i} must be shed, got {other:?}"),
            }
        }
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    while eng.overload_info().brownout_stage != 2 {
        assert!(Instant::now() < deadline, "brownout never escalated to rejecting");
        std::thread::sleep(Duration::from_millis(5));
    }
    match eng.try_open_stream(StreamOptions { model: 0, priority: Priority::Interactive }) {
        Err(RejectReason::Brownout) => {}
        other => panic!("stage-2 brownout must reject admissions, got {other:?}"),
    }

    // Recovery: trickle one frame per >250 ms gap.  Each trickle flush
    // first drains the remaining forced ticks (ratio pinned high), then
    // counts as calm evidence (idle gap => ratio 0) until the EWMA
    // clears the exit bar with hysteresis.
    let mut next = 10usize;
    while next < 50 {
        eng.push_frames(sid, &content[next * fdim..(next + 1) * fdim]).unwrap();
        next += 1;
        std::thread::sleep(Duration::from_millis(300));
        if eng.overload_info().brownout_stage == 0 {
            break;
        }
    }
    assert_eq!(eng.overload_info().brownout_stage, 0, "brownout never recovered");
    // Normal admission is back.
    let (probe, _probe_rx) = eng
        .try_open_stream(StreamOptions { model: 0, priority: Priority::Interactive })
        .expect("admission after recovery");
    eng.finish_stream(probe).unwrap();
    // The survivor drains bit-exact: shedding never touches Interactive.
    eng.push_frames(sid, &content[next * fdim..]).unwrap();
    eng.finish_stream(sid).unwrap();
    let r = s_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(r.end, StreamEnd::Complete);
    assert_eq!(r.phones, want, "brownout changed survivor numerics");

    // Exactly one entry, one recovery, two Bulk victims, one reject.
    let m = eng.metrics();
    assert_eq!(*m.brownout_entries.lock().unwrap(), 1);
    assert_eq!(*m.brownout_exits.lock().unwrap(), 1);
    assert_eq!(*m.shed_streams.lock().unwrap(), 2);
    assert_eq!(m.per_model.lock().unwrap()[0].shed_streams, 2);
    assert_eq!(*m.brownout_rejects.lock().unwrap(), 1);
    // The realized schedule is exactly the scripted one, twice over:
    // every forced tick fired once, nothing else fired at all.
    let log = p.schedule_log();
    assert_eq!(log.len(), FORCED, "forced ticks fired exactly once each: {log:?}");
    assert!(log.iter().all(|l| l.contains("overload_tick")), "{log:?}");
}

/// Zero-downtime swap with a health-checked rollback: an injected canary
/// failure rolls the swap back (old model keeps serving, zero streams
/// cancelled, replacement slot torn down), a retry with a clean canary
/// completes, newcomers dialing the old id are redirected to the
/// replacement, and the mid-utterance survivor drains bit-exact on the
/// old model throughout both swaps.
#[test]
fn swap_rollback_on_canary_failure_keeps_old_serving() {
    let p = plan("7:canary_fail@1");
    let (model_a, eng) = small_engine(Some(p.clone()), None, None);
    let qam_b = common::random_model_seeded(2, 12, Some(6), 0x51AB);
    let model_b = Arc::new(AcousticModel::from_qam(&qam_b, ExecMode::Quant).unwrap());

    let total = 24usize;
    let half = 12 * spec::FEAT_DIM;
    let content = frames(total, 0xAB1);
    let want_a = greedy_ref(&model_a, &content, total);

    // The survivor holds a live, half-pushed stream on the old model.
    let (sid, s_rx) = eng
        .try_open_stream(StreamOptions { model: 0, priority: Priority::Interactive })
        .expect("survivor admission");
    eng.push_frames(sid, &content[..half]).unwrap();

    // Swap 1: the canary fails (injected) before taking any traffic.
    let err = eng
        .swap_model(0, model_b.clone(), ModelParams { weight: 1, lanes: Some(2) })
        .expect_err("injected canary failure must roll the swap back");
    assert!(err.contains("rolled back"), "{err}");
    assert!(err.contains("injected canary failure"), "{err}");
    assert_eq!(p.schedule_log().len(), 1, "{:?}", p.schedule_log());
    assert!(p.schedule_log()[0].contains("canary_fail"));
    assert_eq!(*eng.metrics().swap_rollbacks.lock().unwrap(), 1);
    assert_eq!(*eng.metrics().model_swaps.lock().unwrap(), 0);
    // The old model was never touched: zero cancelled streams.
    assert_eq!(*eng.metrics().forced_cancels.lock().unwrap(), 0);
    assert_eq!(*eng.metrics().shed_streams.lock().unwrap(), 0);
    assert_eq!(*eng.metrics().reaped_streams.lock().unwrap(), 0);
    // The failed replacement's slot tears down; only the old row stays.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let reg = eng.registry();
        if reg.len() == 1 && reg[0].id == 0 && !reg[0].draining {
            break;
        }
        assert!(Instant::now() < deadline, "rolled-back slot never tore down: {reg:?}");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Swap 2 (canary arrival 2 — no scripted fault): completes while the
    // survivor is still live on the old model.
    let new_id = eng
        .swap_model(0, model_b.clone(), ModelParams { weight: 1, lanes: Some(2) })
        .expect("clean canary: swap completes");
    assert_eq!(*eng.metrics().model_swaps.lock().unwrap(), 1);
    let reg = eng.registry();
    let old = reg.iter().find(|e| e.id == 0).expect("old row drains with a survivor");
    assert!(old.draining, "the swapped-out model must drain, not die");

    // Newcomers dialing the old id land on the replacement.
    let n = 8usize;
    let nb = frames(n, 0xAB2);
    let r = run_utt(&eng, 0, &nb);
    assert_eq!(r.end, StreamEnd::Complete);
    assert_eq!(r.phones, greedy_ref(&model_b, &nb, n), "newcomer must run on the replacement");

    // The survivor drains on the old model, bit-exact across both swaps.
    eng.push_frames(sid, &content[half..]).unwrap();
    eng.finish_stream(sid).unwrap();
    let r = s_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(r.end, StreamEnd::Complete);
    assert_eq!(r.phones, want_a, "swap changed survivor numerics");
    assert_eq!(*eng.metrics().forced_cancels.lock().unwrap(), 0, "zero cancels on old model");

    // The old slot tears down once drained; the replacement remains.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let reg = eng.registry();
        if reg.len() == 1 && reg[0].id == new_id {
            break;
        }
        assert!(Instant::now() < deadline, "swapped-out slot never tore down: {reg:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Memory-pressure admission control under churn: a byte budget sized
/// for exactly two stream reservations rejects the third admission every
/// round with the machine-readable reason, resident bytes never exceed
/// the budget, reservations return in full when streams drain, an
/// over-budget hot load is refused up front, and the scripted
/// `mem_pressure` fault point forces the same reject path once.
#[test]
fn memory_pressure_rejects_under_churn() {
    let qam = common::random_model(2, 16, Some(8));
    let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
    let blob = model.lane_state_bytes();
    let arena = model.arena_bytes(2);
    assert!(blob > 0 && arena > 0);
    let budget = arena + 2 * blob;
    let decoder =
        Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let mut cfg = chaos_config(2, None, None, None);
    cfg.mem_budget = Some(budget);
    let eng = Arc::new(Engine::start(model.clone(), decoder, cfg));

    let n = 4usize;
    for round in 0..4u64 {
        let (a, rx_a) = eng.try_open_stream(StreamOptions::default()).expect("round admit a");
        let (b, rx_b) = eng.try_open_stream(StreamOptions::default()).expect("round admit b");
        match eng.try_open_stream(StreamOptions::default()) {
            Err(RejectReason::MemoryPressure { resident, budget: bb }) => {
                assert_eq!((resident, bb), (budget, budget), "round {round}");
            }
            other => panic!("round {round}: expected memory-pressure reject, got {other:?}"),
        }
        assert!(eng.overload_info().resident_bytes <= budget, "round {round}: over budget");
        for (i, (id, rx)) in [(a, rx_a), (b, rx_b)].into_iter().enumerate() {
            let f = frames(n, 0xC0DE + round * 10 + i as u64);
            let want = greedy_ref(&model, &f, n);
            eng.push_frames(id, &f).unwrap();
            eng.finish_stream(id).unwrap();
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(r.end, StreamEnd::Complete);
            assert_eq!(r.phones, want, "round {round} stream {i}: numerics under pressure");
        }
        // Reservations come back in full before the next round.
        let deadline = Instant::now() + Duration::from_secs(5);
        while eng.overload_info().resident_bytes != arena {
            assert!(Instant::now() < deadline, "round {round}: reservations leaked");
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    assert_eq!(*eng.metrics().mem_pressure_rejects.lock().unwrap(), 4);
    // An over-budget hot load is refused before touching the ledger.
    let qam_b = common::random_model_seeded(2, 16, Some(8), 0x0DDB);
    let model_b = Arc::new(AcousticModel::from_qam(&qam_b, ExecMode::Quant).unwrap());
    let err = eng
        .load_model(model_b, ModelParams { weight: 1, lanes: Some(4) })
        .expect_err("over-budget load must be refused");
    assert!(err.contains("memory pressure"), "{err}");
    assert_eq!(eng.overload_info().resident_bytes, arena, "refused load must not leak");

    // The scripted fault point forces the same reject once, budget-free.
    let p = plan("5:mem_pressure@1");
    let (m2, eng2) = small_engine(Some(p.clone()), None, None);
    match eng2.try_open_stream(StreamOptions::default()) {
        Err(RejectReason::MemoryPressure { resident, budget: 0 }) => {
            assert_eq!(resident, m2.arena_bytes(2), "forced reject reports the live ledger");
        }
        other => panic!("forced mem_pressure must reject, got {other:?}"),
    }
    assert_eq!(p.schedule_log().len(), 1, "{:?}", p.schedule_log());
    assert!(p.schedule_log()[0].contains("mem_pressure"));
    assert_eq!(*eng2.metrics().mem_pressure_rejects.lock().unwrap(), 1);
    let (id, _rx) = eng2.try_open_stream(StreamOptions::default()).expect("fault cleared");
    eng2.finish_stream(id).unwrap();
}

/// Flight-recorder acceptance: a scripted backend panic deterministically
/// produces a postmortem dump whose events reconcile with the fault
/// counters — one `quarantine` instant matching `quarantined_jobs`, one
/// `cancel` instant for the stream the quarantine killed — and both the
/// dump and the engine's `'X'`-frame export render as well-formed
/// Chrome-trace JSON (written to `TRACE_chaos.json`, uploaded by the
/// trace CI job).
#[test]
fn backend_panic_postmortem_is_deterministic_and_reconciles() {
    use quantasr::obs;

    // Same scripted point as the quarantine scenario: fire on the first
    // batched-step arrival, only when model 1 steps.
    let p = plan("9:backend_panic@1#1");
    let (_model_a, eng) = small_engine(Some(p.clone()), None, None);
    assert!(obs::enabled(), "chaos tracing scenarios need the recorder on (QUANTASR_TRACE)");
    let qam_b = common::random_model_seeded(2, 12, Some(6), 0xBAD);
    let model_b = Arc::new(AcousticModel::from_qam(&qam_b, ExecMode::Quant).unwrap());
    let id_b = eng
        .load_model(model_b, ModelParams { weight: 1, lanes: Some(2) })
        .expect("hot load");

    let (sid, srx) = eng
        .try_open_stream(StreamOptions { model: id_b, priority: Priority::Interactive })
        .expect("admission");
    eng.push_frames(sid, &frames(10, 0xEE)).unwrap();
    let r = srx.recv_timeout(Duration::from_secs(10)).expect("quarantine cancel within 10 s");
    match &r.end {
        StreamEnd::Cancelled(why) => assert!(why.contains("quarantined"), "{why}"),
        other => panic!("want a quarantine cancel, got {other:?}"),
    }
    assert!(r.trace != 0, "engine-opened streams carry a trace id");

    // Exactly one dump, with the quarantine trigger, scoped to this
    // engine — the same plan always yields the same incident record.
    // Bounded poll: the cancel result races the dump by a few
    // instructions (the panic arm cancels victims, then dumps).
    let my_dumps = || {
        obs::postmortems()
            .into_iter()
            .filter(|d| d.engine == eng.obs_id() && d.trigger == "backend_panic_quarantine")
            .collect::<Vec<_>>()
    };
    let deadline = Instant::now() + Duration::from_secs(10);
    while my_dumps().is_empty() {
        assert!(Instant::now() < deadline, "postmortem never recorded");
        std::thread::sleep(Duration::from_millis(5));
    }
    let dumps = my_dumps();
    assert_eq!(dumps.len(), 1, "one scripted panic, one postmortem");
    let dump = &dumps[0];
    assert!(!dump.events.is_empty(), "a postmortem must carry its incident window");

    // The dump reconciles with the fault counters: the quarantine and
    // the cancel it forced are both in the window, in that causal order
    // (cancel first — the panic arm cancels the victims, then dumps).
    let quarantines =
        dump.events.iter().filter(|e| e.kind == obs::EventKind::Quarantine).count() as u64;
    let cancels = dump.events.iter().filter(|e| e.kind == obs::EventKind::Cancel).count() as u64;
    assert_eq!(quarantines, *eng.metrics().quarantined_jobs.lock().unwrap());
    assert_eq!(cancels, 1, "the quarantined model had exactly one live stream");
    let q_ev = dump.events.iter().find(|e| e.kind == obs::EventKind::Quarantine).unwrap();
    assert_eq!(q_ev.model, id_b as u16, "quarantine event names the panicked model");
    let c_ev = dump.events.iter().find(|e| e.kind == obs::EventKind::Cancel).unwrap();
    assert_eq!(c_ev.stream, sid, "cancel event names the quarantined stream");

    // Both export surfaces are well-formed Chrome-trace JSON arrays.
    for (what, json) in
        [("postmortem", obs::chrome_trace_json(&dump.events)), ("export", eng.trace_json())]
    {
        match quantasr::io::json::Json::parse(&json) {
            Ok(quantasr::io::json::Json::Arr(evs)) => {
                assert!(!evs.is_empty(), "{what}: trace must not be empty here")
            }
            Ok(other) => panic!("{what}: want a JSON array, got {other:?}"),
            Err(e) => panic!("{what}: invalid Chrome-trace JSON: {e}"),
        }
    }
    std::fs::write("TRACE_chaos.json", eng.trace_json()).expect("write trace artifact");
}
