//! Property tests for the TCP wire parsers (`coordinator::server`).
//!
//! Contract under test: the frame readers are **total** over arbitrary
//! byte streams — every input yields `Ok` or a structured
//! [`ServeError`], never a panic, never an attacker-sized allocation.
//! Three input distributions: pure noise, valid frames (round-trip),
//! and valid frames with seeded mutations (truncation, bit flips,
//! length-field corruption), covering every tag including the new
//! `'C'`/`'E'` terminal and `'D'` admin frames.

use std::io::Cursor;

use quantasr::coordinator::server::{
    read_client_frame, read_server_frame, ClientFrame, ServerFrame, MAX_AUDIO_SAMPLES,
    MAX_TEXT_BYTES,
};
use quantasr::sched::Priority;
use quantasr::util::prop::{forall, Gen};

/// Serialize one random-but-valid client frame, returning the bytes and
/// the expected parse.
fn gen_client_frame(g: &mut Gen) -> (Vec<u8>, ClientFrame) {
    match g.usize_in(0, 10) {
        0 => {
            let p = if g.bool() { Priority::Interactive } else { Priority::Bulk };
            (vec![b'P', p.to_wire()], ClientFrame::Priority(p))
        }
        1 => {
            let m = g.usize_in(0, 500) as u32;
            let mut b = vec![b'M'];
            b.extend_from_slice(&m.to_le_bytes());
            (b, ClientFrame::Model(m))
        }
        2 => {
            let pcm = g.vec_f32(g.usize_in(0, 64), -1.0, 1.0);
            let mut b = vec![b'A'];
            b.extend_from_slice(&(pcm.len() as u32).to_le_bytes());
            for v in &pcm {
                b.extend_from_slice(&v.to_le_bytes());
            }
            (b, ClientFrame::Audio(pcm))
        }
        3 => (vec![b'E'], ClientFrame::End),
        4 => {
            let path: String = (0..g.usize_in(0, 40)).map(|_| 'p').collect();
            let weight = g.usize_in(1, 9) as u32;
            let lanes = g.usize_in(0, 8) as u32;
            let mut b = vec![b'L'];
            b.extend_from_slice(&weight.to_le_bytes());
            b.extend_from_slice(&lanes.to_le_bytes());
            b.extend_from_slice(&(path.len() as u32).to_le_bytes());
            b.extend_from_slice(path.as_bytes());
            (b, ClientFrame::Load { weight, lanes, path })
        }
        5 => {
            let id = g.usize_in(0, 31) as u32;
            let mut b = vec![b'U'];
            b.extend_from_slice(&id.to_le_bytes());
            (b, ClientFrame::Unload(id))
        }
        6 => {
            let id = g.usize_in(0, 31) as u32;
            let deadline_ms = g.usize_in(0, 60_000) as u32;
            let force = g.bool();
            let mut b = vec![b'D'];
            b.extend_from_slice(&id.to_le_bytes());
            b.extend_from_slice(&deadline_ms.to_le_bytes());
            b.push(u8::from(force));
            (b, ClientFrame::UnloadDeadline { id, deadline_ms, force })
        }
        7 => {
            let path: String = (0..g.usize_in(0, 40)).map(|_| 's').collect();
            let old = g.usize_in(0, 31) as u32;
            let weight = g.usize_in(1, 9) as u32;
            let lanes = g.usize_in(0, 8) as u32;
            let mut b = vec![b'S'];
            b.extend_from_slice(&old.to_le_bytes());
            b.extend_from_slice(&weight.to_le_bytes());
            b.extend_from_slice(&lanes.to_le_bytes());
            b.extend_from_slice(&(path.len() as u32).to_le_bytes());
            b.extend_from_slice(path.as_bytes());
            (b, ClientFrame::Swap { old, weight, lanes, path })
        }
        8 => (vec![b'T'], ClientFrame::Metrics),
        9 => (vec![b'X'], ClientFrame::Trace),
        _ => (vec![b'Q'], ClientFrame::Query),
    }
}

/// Serialize one random-but-valid server frame.
fn gen_server_frame(g: &mut Gen) -> Vec<u8> {
    fn text(tag: u8, g: &mut Gen) -> Vec<u8> {
        let n = g.usize_in(0, 60);
        let mut b = vec![tag];
        b.extend_from_slice(&(n as u32).to_le_bytes());
        b.extend((0..n).map(|_| b'r'));
        b
    }
    // Terminal frames (F/R/C/E) end with the trailing u64 trace id.
    fn trace_id(g: &mut Gen) -> [u8; 8] {
        (g.usize_in(0, 1 << 40) as u64).to_le_bytes()
    }
    match g.usize_in(0, 7) {
        0 => {
            let words = g.vec_ids(g.usize_in(0, 16), 1000);
            let phones = g.vec_ids(g.usize_in(0, 16), 50);
            let mut b = vec![b'F'];
            b.extend_from_slice(&(words.len() as u32).to_le_bytes());
            for w in &words {
                b.extend_from_slice(&w.to_le_bytes());
            }
            b.extend_from_slice(&(phones.len() as u32).to_le_bytes());
            for p in &phones {
                b.extend_from_slice(&p.to_le_bytes());
            }
            b.extend_from_slice(&g.f32_in(0.0, 100.0).to_le_bytes());
            b.extend_from_slice(&trace_id(g));
            b
        }
        1 => {
            let mut b = text(b'R', g);
            b.extend_from_slice(&trace_id(g));
            b
        }
        2 => {
            let mut b = vec![b'O'];
            b.extend_from_slice(&(g.usize_in(0, 31) as u32).to_le_bytes());
            b
        }
        3 => {
            let mut b = text(b'C', g);
            b.extend_from_slice(&trace_id(g));
            b
        }
        4 => {
            let mut b = text(b'E', g);
            b.extend_from_slice(&trace_id(g));
            b
        }
        5 => text(b'T', g),
        6 => {
            // 'X' trace export: any bytes are accepted at the wire layer
            // (JSON validity is the exporter's contract, not the parser's).
            text(b'X', g)
        }
        _ => {
            let rows = g.usize_in(0, 4);
            let mut b = vec![b'Q'];
            b.push(g.usize_in(0, 2) as u8); // brownout stage
            b.extend_from_slice(&(g.usize_in(0, 1 << 20) as u64).to_le_bytes()); // resident
            b.extend_from_slice(&(g.usize_in(0, 1 << 20) as u64).to_le_bytes()); // budget
            b.extend_from_slice(&(rows as u32).to_le_bytes());
            for i in 0..rows {
                b.extend_from_slice(&(i as u32).to_le_bytes());
                b.push(g.usize_in(0, 2) as u8); // status: loaded/draining/quarantined
                b.extend_from_slice(&(g.usize_in(1, 9) as u32).to_le_bytes());
                b.extend_from_slice(&(g.usize_in(1, 8) as u32).to_le_bytes());
                b.extend_from_slice(&(g.usize_in(0, 8) as u32).to_le_bytes());
                b.extend_from_slice(&(g.usize_in(0, 1 << 16) as u64).to_le_bytes()); // arena
                b.extend_from_slice(&(g.usize_in(0, 1 << 16) as u64).to_le_bytes()); // reserved
                b.extend_from_slice(&(g.usize_in(0, 1 << 16) as u64).to_le_bytes()); // parked
                let name_len = g.usize_in(0, 12);
                b.extend_from_slice(&(name_len as u32).to_le_bytes());
                b.extend((0..name_len).map(|_| b'm'));
                let scheme_len = g.usize_in(0, 14); // scheme text after name
                b.extend_from_slice(&(scheme_len as u32).to_le_bytes());
                b.extend((0..scheme_len).map(|_| b'q'));
            }
            b
        }
    }
}

/// Corrupt a valid encoding: truncate, flip a bit, or overwrite a byte.
fn mutate(g: &mut Gen, mut b: Vec<u8>) -> Vec<u8> {
    if b.is_empty() {
        return b;
    }
    match g.usize_in(0, 2) {
        0 => {
            let keep = g.usize_in(0, b.len() - 1);
            b.truncate(keep);
        }
        1 => {
            let at = g.usize_in(0, b.len() - 1);
            b[at] ^= 1 << g.usize_in(0, 7);
        }
        _ => {
            let at = g.usize_in(0, b.len() - 1);
            b[at] = g.usize_in(0, 255) as u8;
        }
    }
    b
}

#[test]
fn client_parser_is_total_over_noise() {
    forall("client noise", 4000, 0xC11E_17, |g| {
        let bytes: Vec<u8> = (0..g.usize_in(0, 64)).map(|_| g.usize_in(0, 255) as u8).collect();
        // Ok(None) on empty, Ok(Some) if the noise happens to spell a
        // frame, Err otherwise — the assertion is simply "returns".
        let _ = read_client_frame(&mut Cursor::new(bytes));
    });
}

#[test]
fn server_parser_is_total_over_noise() {
    forall("server noise", 4000, 0x5E11_E7, |g| {
        let bytes: Vec<u8> = (0..g.usize_in(0, 64)).map(|_| g.usize_in(0, 255) as u8).collect();
        let _ = read_server_frame(&mut Cursor::new(bytes));
    });
}

#[test]
fn valid_client_frames_round_trip() {
    forall("client round-trip", 2000, 0xF00D, |g| {
        let (bytes, want) = gen_client_frame(g);
        let got = read_client_frame(&mut Cursor::new(bytes))
            .expect("valid frame must parse")
            .expect("valid frame is not EOF");
        assert_eq!(got, want);
    });
}

#[test]
fn valid_server_frames_parse() {
    forall("server frames parse", 2000, 0xBEEF, |g| {
        let bytes = gen_server_frame(g);
        let frame = read_server_frame(&mut Cursor::new(bytes)).expect("valid frame must parse");
        // Every variant is reachable from the generator; touch it so a
        // parser that collapses arms would fail the round-trip test.
        let _ = frame.kind();
    });
}

#[test]
fn mutated_client_frames_never_panic() {
    forall("client mutations", 4000, 0xDEAD_01, |g| {
        let (bytes, _) = gen_client_frame(g);
        let mutated = mutate(g, bytes);
        let _ = read_client_frame(&mut Cursor::new(mutated));
    });
}

#[test]
fn mutated_server_frames_never_panic() {
    forall("server mutations", 4000, 0xDEAD_02, |g| {
        let bytes = gen_server_frame(g);
        let mutated = mutate(g, bytes);
        let _ = read_server_frame(&mut Cursor::new(mutated));
    });
}

/// Hostile length prefixes on a short input must be refused without
/// allocating anywhere near the declared size.
#[test]
fn hostile_length_prefixes_are_bounded() {
    forall("hostile lengths", 1000, 0x0DD5, |g| {
        // Every bound in play (audio samples, text, result tokens,
        // registry rows) sits at or below MAX_AUDIO_SAMPLES.
        let decl = g.usize_in(MAX_AUDIO_SAMPLES.max(MAX_TEXT_BYTES) + 1, u32::MAX as usize) as u32;

        // Client-side tags whose body starts with a length prefix.
        let tag = [b'A', b'L'][g.usize_in(0, 1)];
        let mut b = vec![tag];
        if tag == b'L' {
            b.extend_from_slice(&1u32.to_le_bytes());
            b.extend_from_slice(&0u32.to_le_bytes());
        }
        b.extend_from_slice(&decl.to_le_bytes());
        assert!(read_client_frame(&mut Cursor::new(b)).is_err());

        // Server-side tags whose body starts with a length prefix.
        let tag = [b'R', b'C', b'E', b'F', b'Q'][g.usize_in(0, 4)];
        let mut b = vec![tag];
        b.extend_from_slice(&decl.to_le_bytes());
        assert!(read_server_frame(&mut Cursor::new(b)).is_err());
    });
}
