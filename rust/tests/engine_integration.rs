//! Integration tests of the serving engine (no artifacts needed — random
//! model) : conservation, ordering, batched-vs-sequential equivalence,
//! backpressure, and concurrent-stream stress.

mod common;

use std::sync::Arc;

use quantasr::coordinator::batcher::BatchPolicy;
use quantasr::coordinator::{Engine, EngineConfig};
use quantasr::decoder::DecoderConfig;
use quantasr::eval::build_decoder;
use quantasr::frontend::spec;
use quantasr::nn::{AcousticModel, ExecMode};
use quantasr::sim::World;
use quantasr::util::rng::Xoshiro256;

fn engine(max_batch: usize) -> (Arc<Engine>, Arc<AcousticModel>) {
    let qam = common::random_model(2, 16, Some(8));
    let model = Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap());
    let decoder = Arc::new(build_decoder(&World::new(), DecoderConfig { beam: 4, ..Default::default() }));
    let cfg = EngineConfig {
        policy: BatchPolicy { max_batch, deadline: std::time::Duration::from_millis(2) },
        decode_workers: 2,
        max_pending_frames: 32,
        ..EngineConfig::default()
    };
    (Arc::new(Engine::start(model.clone(), decoder, cfg)), model)
}

fn frames(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::new(seed);
    let mut v = vec![0f32; n * spec::FEAT_DIM];
    for x in v.iter_mut() {
        *x = rng.normal() as f32;
    }
    v
}

#[test]
fn every_stream_gets_exactly_one_result_with_all_frames() {
    let (eng, _) = engine(4);
    let n_streams = 12;
    let mut rxs = Vec::new();
    for s in 0..n_streams {
        let (id, rx) = eng.open_stream();
        let n = 5 + s % 7;
        eng.push_frames(id, &frames(n, s as u64)).unwrap();
        eng.finish_stream(id).unwrap();
        rxs.push((rx, n));
    }
    for (rx, n) in rxs {
        let r = rx.recv_timeout(std::time::Duration::from_secs(20)).unwrap();
        assert_eq!(r.num_frames, n, "frame conservation");
    }
}

#[test]
fn batched_results_match_unbatched() {
    // The same stream content must produce identical posterior-derived
    // phones whether it shares batches with others or runs alone.
    let (eng_batch, model) = engine(6);
    let content: Vec<Vec<f32>> = (0..6).map(|s| frames(12, 100 + s)).collect();

    // Reference: direct single-utterance forward + greedy.
    let want: Vec<Vec<u32>> = content
        .iter()
        .map(|f| {
            let lp = model.forward_utt(f, 12);
            quantasr::decoder::ctc::greedy(&lp, model.num_labels())
        })
        .collect();

    let mut rxs = Vec::new();
    for f in &content {
        let (id, rx) = eng_batch.open_stream();
        eng_batch.push_frames(id, f).unwrap();
        eng_batch.finish_stream(id).unwrap();
        rxs.push(rx);
    }
    for (rx, want_phones) in rxs.into_iter().zip(want) {
        let r = rx.recv_timeout(std::time::Duration::from_secs(20)).unwrap();
        assert_eq!(r.phones, want_phones, "cross-stream batching changed numerics");
    }
}

#[test]
fn lane_churn_with_more_streams_than_lanes() {
    // 6 streams over 2 arena lanes with interleaved chunked pushes: forces
    // lane admission, eviction of idle holders (a third stream cannot make
    // progress before an eviction happens, since lanes are only *released*
    // at stream drain), state park/restore, and release.  Lane residency
    // must be invisible: every stream's phones match its solo reference.
    let (eng, model) = engine(2);
    let n_streams = 6usize;
    let (chunks, chunk_len) = (4usize, 3usize);
    let total = chunks * chunk_len;
    let content: Vec<Vec<f32>> =
        (0..n_streams).map(|s| frames(total, 500 + s as u64)).collect();
    let want: Vec<Vec<u32>> = content
        .iter()
        .map(|f| {
            let lp = model.forward_utt(f, total);
            quantasr::decoder::ctc::greedy(&lp, model.num_labels())
        })
        .collect();

    let d = spec::FEAT_DIM;
    let mut ids = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..n_streams {
        let (id, rx) = eng.open_stream();
        ids.push(id);
        rxs.push(rx);
    }
    // Round-robin chunk pushes with pauses so holders go idle between
    // chunks and waiting streams must evict them.
    for c in 0..chunks {
        for (i, &id) in ids.iter().enumerate() {
            let chunk = &content[i][c * chunk_len * d..(c + 1) * chunk_len * d];
            eng.push_frames(id, chunk).unwrap();
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    for &id in &ids {
        eng.finish_stream(id).unwrap();
    }
    for (rx, want_phones) in rxs.into_iter().zip(want) {
        let r = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(r.num_frames, total, "frame conservation under lane churn");
        assert_eq!(r.phones, want_phones, "lane churn changed numerics");
    }
    // With 6 streams contending for 2 lanes and releases only at drain,
    // at least one eviction must have occurred for stream 3+ to progress.
    assert!(
        *eng.metrics().evictions.lock().unwrap() >= 1,
        "expected lane evictions under contention"
    );
}

#[test]
fn empty_stream_finishes_cleanly() {
    let (eng, _) = engine(4);
    let (id, rx) = eng.open_stream();
    eng.finish_stream(id).unwrap();
    let r = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
    assert_eq!(r.num_frames, 0);
    assert!(r.words.is_empty());
}

#[test]
fn backpressure_does_not_deadlock() {
    // Push far more frames than max_pending (32) in one call.
    let (eng, _) = engine(2);
    let (id, rx) = eng.open_stream();
    eng.push_frames(id, &frames(200, 7)).unwrap();
    eng.finish_stream(id).unwrap();
    let r = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
    assert_eq!(r.num_frames, 200);
}

#[test]
fn concurrent_producers_stress() {
    let (eng, _) = engine(8);
    std::thread::scope(|scope| {
        for s in 0..8 {
            let eng = &eng;
            scope.spawn(move || {
                for u in 0..4 {
                    let (id, rx) = eng.open_stream();
                    let n = 6 + (s + u) % 9;
                    eng.push_frames(id, &frames(n, (s * 100 + u) as u64)).unwrap();
                    eng.finish_stream(id).unwrap();
                    let r = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
                    assert_eq!(r.num_frames, n);
                }
            });
        }
    });
    assert_eq!(*eng.metrics().utterances.lock().unwrap(), 32);
    // batching actually happened under concurrency
    let bs = eng.metrics().batch_size.summary();
    assert!(bs.count > 0);
}

#[test]
fn unknown_stream_errors() {
    let (eng, _) = engine(2);
    assert!(eng.push_frames(999, &frames(1, 0)).is_err());
    assert!(eng.finish_stream(999).is_err());
}

#[test]
fn requantize_bits_degrades_gracefully() {
    // 8-bit ≈ float; 2-bit destroys the model. (E5 mechanism, unit-scale.)
    let qam = common::random_model(2, 16, None);
    let m_f = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
    let mut m8 = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
    m8.requantize_bits(8, true);
    let mut m2 = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
    m2.requantize_bits(2, true);
    let mut rng = Xoshiro256::new(0xB17);
    let mut x = vec![0f32; 10 * spec::FEAT_DIM];
    for v in x.iter_mut() {
        *v = rng.normal() as f32;
    }
    let lf = m_f.forward_utt(&x, 10);
    let l8 = m8.forward_utt(&x, 10);
    let l2 = m2.forward_utt(&x, 10);
    let err = |a: &[f32], b: &[f32]| {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
    };
    let e8 = err(&lf, &l8);
    let e2 = err(&lf, &l2);
    assert!(e8 < 0.5, "8-bit err {e8}");
    assert!(e2 > 4.0 * e8, "2-bit should be much worse: {e2} vs {e8}");
}

#[test]
fn exec_mode_parse() {
    assert_eq!(ExecMode::parse("float").unwrap(), ExecMode::Float);
    assert_eq!(ExecMode::parse("match").unwrap(), ExecMode::Float);
    assert_eq!(ExecMode::parse("mismatch").unwrap(), ExecMode::Quant);
    assert_eq!(ExecMode::parse("quant-all").unwrap(), ExecMode::QuantAll);
    assert!(ExecMode::parse("bogus").is_err());
}
