"""Export trained models to the ``.qam`` binary format (io/model_fmt.rs).

Layout (little-endian):
    magic  b"QAM1"
    u32    version (1)
    u32    header_len;  header_len bytes of JSON (architecture + flags)
    u32    n_tensors
    per tensor:
        u32 name_len; name bytes (utf-8)
        u8  dtype         (0 = f32, 1 = u8-quantized)
        u32 ndim; u32 shape[ndim]
        if dtype == 1:  f32 vmin, f32 q      (zero point = round(q*vmin))
        data              (f32 LE or u8, row-major)

Weights of a quantized export hold the eq. (2) values
``V' = round(Q·V) − round(Q·vmin) ∈ [0, 255]``; biases stay f32 (Figure 1
applies them after recovery).  The rust loader recovers with eq. (3) for the
float path or feeds V' straight into the integer GEMM.
"""

from __future__ import annotations

import json
import struct

import numpy as np

from . import quantlib
from .model import ModelConfig

MAGIC = b"QAM1"

F32 = 0
U8Q = 1


def _np(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float32)


def _quantize_np(v: np.ndarray, scale: float = quantlib.S):
    """Eq. 2 on the host; returns (u8 values, vmin, q).  ``scale`` is
    2^bits − 1 (storage stays u8 for any bits ≤ 8; recovery only needs q)."""
    vmin = float(v.min())
    vmax = float(v.max())
    rng = max(vmax - vmin, 1e-6)
    q = scale / rng
    zp = round(q * vmin)
    vq = np.clip(np.round(q * v) - zp, 0, scale).astype(np.uint8)
    return vq, vmin, q


def write_qam(
    path: str,
    params: dict,
    cfg: ModelConfig,
    quantized: bool,
    quantize_output: bool = False,
    meta: dict | None = None,
    bits: int = 8,
):
    """Serialize ``params`` (jnp or np arrays keyed like model.init_params).

    ``quantized`` — store weight matrices as u8 (eq. 2) with (vmin, q);
    ``quantize_output`` — also quantize the softmax matrix ('quant-all').
    """
    header = {
        "name": cfg.name,
        "num_layers": cfg.num_layers,
        "cell_dim": cfg.cell_dim,
        "proj_dim": -1 if cfg.proj_dim is None else cfg.proj_dim,
        "input_dim": cfg.input_dim,
        "num_labels": cfg.num_labels,
        "quantized": quantized,
        "quantize_output": quantize_output,
        "param_count": cfg.param_count(),
    }
    if meta:
        header["meta"] = meta
    hdr = json.dumps(header).encode()

    names = sorted(params.keys())
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(struct.pack("<I", 1))
        fh.write(struct.pack("<I", len(hdr)))
        fh.write(hdr)
        fh.write(struct.pack("<I", len(names)))
        for name in names:
            v = _np(params[name])
            is_matrix = v.ndim == 2
            is_out = name.startswith("out.")
            as_quant = (
                quantized and is_matrix and (quantize_output or not is_out)
            )
            nb = name.encode()
            fh.write(struct.pack("<I", len(nb)))
            fh.write(nb)
            fh.write(struct.pack("<B", U8Q if as_quant else F32))
            fh.write(struct.pack("<I", v.ndim))
            for d in v.shape:
                fh.write(struct.pack("<I", d))
            if as_quant:
                vq, vmin, q = _quantize_np(v, scale=float((1 << bits) - 1))
                fh.write(struct.pack("<ff", vmin, q))
                fh.write(vq.tobytes())
            else:
                fh.write(v.astype("<f4").tobytes())


def read_qam(path: str):
    """Read back (header, params-as-float) — used by tests for round-trip."""
    with open(path, "rb") as fh:
        assert fh.read(4) == MAGIC
        (_ver,) = struct.unpack("<I", fh.read(4))
        (hlen,) = struct.unpack("<I", fh.read(4))
        header = json.loads(fh.read(hlen))
        (n,) = struct.unpack("<I", fh.read(4))
        params = {}
        qinfo = {}
        for _ in range(n):
            (nl,) = struct.unpack("<I", fh.read(4))
            name = fh.read(nl).decode()
            (dtype,) = struct.unpack("<B", fh.read(1))
            (nd,) = struct.unpack("<I", fh.read(4))
            shape = struct.unpack(f"<{nd}I", fh.read(4 * nd))
            count = int(np.prod(shape))
            if dtype == U8Q:
                vmin, q = struct.unpack("<ff", fh.read(8))
                vq = np.frombuffer(fh.read(count), dtype=np.uint8)
                zp = round(q * vmin)
                v = ((vq.astype(np.float64) + zp) / q).astype(np.float32)
                qinfo[name] = (vmin, q)
            else:
                v = np.frombuffer(fh.read(4 * count), dtype="<f4")
            params[name] = v.reshape(shape).copy()
        return header, params, qinfo


def read_qam_raw(path: str):
    """Read (header, records) keeping quantized tensors in u8 form.

    records: name → (dtype, array, vmin, q); array is u8 V' for U8Q tensors
    and f32 otherwise (vmin/q are None then).  Used by aot.py to bake the
    exact stored weights into the AOT inference graphs.
    """
    with open(path, "rb") as fh:
        assert fh.read(4) == MAGIC
        (_ver,) = struct.unpack("<I", fh.read(4))
        (hlen,) = struct.unpack("<I", fh.read(4))
        header = json.loads(fh.read(hlen))
        (n,) = struct.unpack("<I", fh.read(4))
        records = {}
        for _ in range(n):
            (nl,) = struct.unpack("<I", fh.read(4))
            name = fh.read(nl).decode()
            (dtype,) = struct.unpack("<B", fh.read(1))
            (nd,) = struct.unpack("<I", fh.read(4))
            shape = struct.unpack(f"<{nd}I", fh.read(4 * nd))
            count = int(np.prod(shape))
            if dtype == U8Q:
                vmin, q = struct.unpack("<ff", fh.read(8))
                arr = np.frombuffer(fh.read(count), dtype=np.uint8)
                records[name] = (U8Q, arr.reshape(shape).copy(), vmin, q)
            else:
                arr = np.frombuffer(fh.read(4 * count), dtype="<f4")
                records[name] = (F32, arr.reshape(shape).copy(), None, None)
        return header, records


def config_from_header(header: dict) -> ModelConfig:
    pd = header["proj_dim"]
    return ModelConfig(
        num_layers=header["num_layers"],
        cell_dim=header["cell_dim"],
        proj_dim=None if pd < 0 else pd,
        input_dim=header["input_dim"],
        num_labels=header["num_labels"],
    )
