"""L1 Pallas kernel: the paper's Figure-1 fused quantized layer.

    y = F( R( Q(x) · Wq ) + b )

One kernel performs, tile by tile:
  1. on-the-fly quantization of the float input tile (eq. 2),
  2. the integer matrix multiply on offset-shifted values with int32
     accumulation (eq. 1, the MXU-friendly part),
  3. recovery to float by 1/(Qx·Qw) (eq. 3),
  4. bias add + activation (VPU elementwise), fused so the recovered tile
     never round-trips through HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the weight tile
``[bk, bn]`` and input tile ``[bm, bk]`` live in VMEM via BlockSpec; the
inner ``jnp.dot(..., preferred_element_type=int32)`` targets the MXU int8
path on real hardware; quantize/recover are VPU ops.  The grid walks
(M/bm, N/bn, K/bk) with the K axis innermost so the f32 accumulator tile in
the output block is revisited (standard Pallas matmul accumulation).

Under ``interpret=True`` (required on CPU — real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute) the numerics are identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import quantlib

S = quantlib.S


def _qmatmul_kernel(x_ref, w_ref, b_ref, scale_ref, o_ref, acc_ref,
                    xsum_ref, wsum_ref, *, activation: str, n_k: int,
                    k_total: int):
    """Inner kernel. Grid = (M/bm, N/bn, K/bk); K innermost.

    scale_ref holds [x_q, x_zp, w_q, w_zp] (small vector).
    acc_ref is the int32 VMEM dot accumulator [bm, bn]; xsum_ref [bm, 1] and
    wsum_ref [1, bn] accumulate the per-row/per-col u8 sums for the
    zero-point folding (see quantlib.quantized_matmul_q — the i32 dot only
    sees u8·u8 products, overflow-free).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xsum_ref[...] = jnp.zeros_like(xsum_ref)
        wsum_ref[...] = jnp.zeros_like(wsum_ref)

    x_q, x_zp, w_q, w_zp = (scale_ref[0], scale_ref[1],
                            scale_ref[2], scale_ref[3])

    # (1) quantize the input tile on the fly (eq. 2): V' ∈ [0, 255].
    xq = jnp.clip(jnp.round(x_q * x_ref[...]) - x_zp, 0.0, S)
    wq = w_ref[...]

    # (2) integer tile matmul on the u8 grids, int32 accumulation
    #     (MXU int8 path on real TPU) + running offset sums (VPU).
    acc_ref[...] += jax.lax.dot_general(
        xq.astype(jnp.int32), wq.astype(jnp.int32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    xsum_ref[...] += jnp.sum(xq, axis=1, keepdims=True)
    wsum_ref[...] += jnp.sum(wq, axis=0, keepdims=True)

    # (3)+(4) on the last K step: fold zero points, recover (eq. 1/3),
    # bias, activation, write out.
    @pl.when(k == n_k - 1)
    def _finish():
        full = (
            acc_ref[...].astype(jnp.float32)
            + x_zp * wsum_ref[...]
            + w_zp * xsum_ref[...]
            + jnp.asarray(k_total, jnp.float32) * x_zp * w_zp
        )
        y = full / (x_q * w_q) + b_ref[...]
        if activation == "sigmoid":
            y = jax.nn.sigmoid(y)
        elif activation == "tanh":
            y = jnp.tanh(y)
        elif activation == "relu":
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y


def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is ≤ pref (block shapes must tile)."""
    b = min(dim, pref)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(
    jax.jit,
    static_argnames=("activation", "bm", "bn", "bk", "interpret"),
)
def qmatmul(
    x: jnp.ndarray,          # [M, K] float32
    wq: jnp.ndarray,         # [K, N] float32 holding u8 values (eq. 2 form)
    b: jnp.ndarray,          # [N]
    x_q: jnp.ndarray,        # scalar: input quantization factor Qx
    x_zp: jnp.ndarray,       # scalar: round(Qx * xmin)
    w_q: jnp.ndarray,        # scalar: weight quantization factor Qw
    w_zp: jnp.ndarray,       # scalar: round(Qw * wmin)
    activation: str = "none",
    bm: int = 32,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused quantized ``y = F(R(Q(x)·Wq) + b)``; see module docstring.

    Block sizes were tuned in the L1 perf pass (EXPERIMENTS.md §Perf-L1):
    bn=bk=128 aligns with the 128×128 MXU tile; bm adapts to batch.
    """
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2, (x.shape, wq.shape)
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk
    scales = jnp.stack([
        jnp.asarray(x_q, jnp.float32), jnp.asarray(x_zp, jnp.float32),
        jnp.asarray(w_q, jnp.float32), jnp.asarray(w_zp, jnp.float32),
    ])
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(
            _qmatmul_kernel, activation=activation, n_k=n_k, k_total=k
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((4,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.float32),
            pltpu.VMEM((1, bn), jnp.float32),
        ],
        interpret=interpret,
    )(x, wq, b, scales)


def vmem_bytes(bm: int, bn: int, bk: int) -> int:
    """VMEM footprint estimate for one grid step (DESIGN.md §Perf-L1).

    x tile (f32) + w tile (u8 on real TPU; f32 under interpret — we count
    the TPU layout) + bias + f32 out tile + i32 accumulator, double-buffered
    inputs (×2) as the Mosaic pipeliner would.
    """
    x_t = bm * bk * 4
    w_t = bk * bn * 1
    b_t = bn * 4
    o_t = bm * bn * 4
    acc = bm * bn * 4
    return 2 * (x_t + w_t + b_t) + o_t + acc
