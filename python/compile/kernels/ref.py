"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference here with identical signature
and semantics; pytest (``python/tests/test_kernels.py``) sweeps shapes and
value ranges (hypothesis) asserting allclose between kernel and oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import quantlib
from ..quantlib import QParams


def qmatmul_ref(
    x: jnp.ndarray,
    wq: jnp.ndarray,
    b: jnp.ndarray,
    x_q: jnp.ndarray,
    x_zp: jnp.ndarray,
    w_q: jnp.ndarray,
    w_zp: jnp.ndarray,
    activation: str = "none",
) -> jnp.ndarray:
    """Reference for the Figure-1 fused layer: Q(x) → int matmul → R → +b → F.

    ``x``  — float input, pre-scaled quantization params (x_q, x_zp) supplied
             by the caller (computed from the true min/max outside).
    ``wq`` — weights already in quantized u8-valued form (float dtype).
    The i32 dot runs on the u8 grids; zero points are folded out
    algebraically (same expansion as quantlib.quantized_matmul_q and the
    rust engine) so the accumulator cannot overflow.
    """
    k = x.shape[-1]
    xq = jnp.clip(jnp.round(x_q * x) - x_zp, 0.0, quantlib.S)
    acc = jax.lax.dot_general(
        xq.astype(jnp.int32), wq.astype(jnp.int32),
        dimension_numbers=(((xq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    full = (
        acc
        + x_zp * jnp.sum(wq, axis=0, keepdims=True)
        + w_zp * jnp.sum(xq, axis=-1, keepdims=True)
        + jnp.asarray(k, jnp.float32) * x_zp * w_zp
    )
    y = full / (x_q * w_q) + b
    return apply_activation(y, activation)


def apply_activation(y: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "none":
        return y
    if activation == "sigmoid":
        return jax.nn.sigmoid(y)
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "relu":
        return jax.nn.relu(y)
    raise ValueError(f"unknown activation {activation!r}")


def lstm_elementwise_ref(
    gates: jnp.ndarray, c: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference LSTM cell elementwise update.

    ``gates`` is the [B, 4N] pre-activation (i, f, g, o blocked layout —
    i = gates[:, 0:N] etc.), ``c`` the [B, N] previous cell state.
    Returns (h_new, c_new).  Gate order matches rust/src/nn/lstm.rs and
    model.py.
    """
    n = c.shape[-1]
    i = jax.nn.sigmoid(gates[..., 0 * n:1 * n])
    f = jax.nn.sigmoid(gates[..., 1 * n:2 * n])
    g = jnp.tanh(gates[..., 2 * n:3 * n])
    o = jax.nn.sigmoid(gates[..., 3 * n:4 * n])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def float_matmul_ref(x, w, b, activation: str = "none"):
    """Float baseline for the same fused layer (the 'match' path)."""
    return apply_activation(x @ w + b, activation)
