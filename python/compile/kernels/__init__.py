"""Pallas kernels (L1) and their pure-jnp oracles."""
from . import lstm_step, qmatmul, ref  # noqa: F401
