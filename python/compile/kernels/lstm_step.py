"""L1 Pallas kernel: fused LSTM elementwise cell update.

The LSTM step is two (quantized) matmuls — handled by
:mod:`.qmatmul` — followed by the gate nonlinearities and state update:

    i, f, g, o = split(gates, 4)
    c' = sigmoid(f) * c + sigmoid(i) * tanh(g)
    h' = sigmoid(o) * tanh(c')

This kernel fuses the whole elementwise tail so the [B, 4N] gate
pre-activations are read from VMEM once and (h', c') are produced without
intermediate HBM round-trips.  On the VPU this is a pure elementwise block;
the tile shape follows the gate matmul's output tile.

Gate block layout [i | f | g | o] matches ``ref.lstm_elementwise_ref``,
``model.py`` and ``rust/src/nn/lstm.rs``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_ew_kernel(gates_ref, c_ref, h_out_ref, c_out_ref):
    n = c_ref.shape[-1]
    g = gates_ref[...]
    i_g = jax.nn.sigmoid(g[:, 0 * n:1 * n])
    f_g = jax.nn.sigmoid(g[:, 1 * n:2 * n])
    g_g = jnp.tanh(g[:, 2 * n:3 * n])
    o_g = jax.nn.sigmoid(g[:, 3 * n:4 * n])
    c_new = f_g * c_ref[...] + i_g * g_g
    h_out_ref[...] = o_g * jnp.tanh(c_new)
    c_out_ref[...] = c_new


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def lstm_elementwise(
    gates: jnp.ndarray,   # [B, 4N] pre-activations
    c: jnp.ndarray,       # [B, N] previous cell state
    bm: int = 32,
    interpret: bool = True,
):
    """Fused LSTM cell tail; returns ``(h_new, c_new)``.

    Grid walks the batch in ``bm`` rows; N is kept whole per tile (cells are
    small in this model family: N ≤ 512 ⇒ ≤ 8KB f32 per row-block column,
    well inside VMEM).
    """
    b, four_n = gates.shape
    n = four_n // 4
    assert c.shape == (b, n), (gates.shape, c.shape)
    while b % bm != 0:
        bm -= 1
    grid = (b // bm,)
    return pl.pallas_call(
        _lstm_ew_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4 * n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=interpret,
    )(gates, c)
