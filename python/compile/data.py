"""Synthetic speech world: waveform synthesis, frontend, dataset export.

Replaces the paper's proprietary Google voice-search/dictation corpora
(DESIGN.md §2).  The generative process:

    sentence (bigram/Zipf over 200-word lexicon)
      → phone sequence (lexicon lookup, optional inter-word pauses)
      → waveform (per-phone formant sinusoids + noise, 8 kHz)
      → [multistyle distortion: colored noise + babble + reverb @ SNR]
      → log-mel frontend (25ms/10ms, 16 mel, stack 4 / skip 2 → 64-d @ 20ms)

Everything is deterministic given the split seed.  Discrete structure
(sentences, durations) uses the shared SplitMix64 (bit-identical with
rust/src/sim); bulk float noise uses numpy PCG64 (distribution-identical).

Exports (``python -m compile.data --out ../artifacts``):
    artifacts/data/{train,dev,eval_clean,eval_noisy}.feats   (io/feat_fmt)
    artifacts/golden/frontend_{i}.wav.f32 + .feat.f32        (rust golden tests)
    artifacts/world.json                                     (lexicon/bigram dump)
"""

from __future__ import annotations

import argparse
import json
import os
import struct

import numpy as np

from . import spec
from .spec import SplitMix64, World

# ---------------------------------------------------------------------------
# Frontend (mirrored by rust/src/frontend; golden-tested)
# ---------------------------------------------------------------------------


def mel_scale(f: np.ndarray | float) -> np.ndarray | float:
    return 2595.0 * np.log10(1.0 + np.asarray(f, dtype=np.float64) / 700.0)


def mel_inv(m: np.ndarray | float) -> np.ndarray | float:
    return 700.0 * (10.0 ** (np.asarray(m, dtype=np.float64) / 2595.0) - 1.0)


def mel_filterbank() -> np.ndarray:
    """Triangular mel filterbank [N_MEL, FFT/2+1] (HTK-style)."""
    n_bins = spec.FFT_SIZE // 2 + 1
    freqs = np.arange(n_bins) * spec.SAMPLE_RATE / spec.FFT_SIZE
    mel_pts = np.linspace(
        mel_scale(spec.MEL_FMIN), mel_scale(spec.MEL_FMAX), spec.N_MEL + 2
    )
    hz_pts = mel_inv(mel_pts)
    fb = np.zeros((spec.N_MEL, n_bins), dtype=np.float64)
    for m in range(spec.N_MEL):
        lo, ctr, hi = hz_pts[m], hz_pts[m + 1], hz_pts[m + 2]
        up = (freqs - lo) / (ctr - lo)
        down = (hi - freqs) / (hi - ctr)
        fb[m] = np.clip(np.minimum(up, down), 0.0, None)
    return fb.astype(np.float32)


_FB = None
_WIN = None


def _tables():
    global _FB, _WIN
    if _FB is None:
        _FB = mel_filterbank()
        n = spec.FRAME_LEN
        _WIN = (0.5 - 0.5 * np.cos(2.0 * np.pi * np.arange(n) / (n - 1))).astype(
            np.float32
        )
    return _FB, _WIN


def log_mel(wave: np.ndarray) -> np.ndarray:
    """Waveform → log-mel frames [T_raw, N_MEL]."""
    fb, win = _tables()
    # Preemphasis: x'[n] = x[n] - a*x[n-1]; x'[0] = x[0].
    w = wave.astype(np.float32)
    pre = np.empty_like(w)
    pre[0] = w[0]
    pre[1:] = w[1:] - spec.PREEMPHASIS * w[:-1]
    n_frames = 1 + (len(pre) - spec.FRAME_LEN) // spec.FRAME_HOP
    if n_frames <= 0:
        return np.zeros((0, spec.N_MEL), dtype=np.float32)
    idx = (
        np.arange(spec.FRAME_LEN)[None, :]
        + spec.FRAME_HOP * np.arange(n_frames)[:, None]
    )
    frames = pre[idx] * win[None, :]
    spec_pow = np.abs(np.fft.rfft(frames, n=spec.FFT_SIZE, axis=1)) ** 2
    mel = spec_pow @ fb.T
    return np.log(np.maximum(mel, spec.LOG_FLOOR)).astype(np.float32)


def stack_frames(frames: np.ndarray) -> np.ndarray:
    """Stack ``STACK`` frames (right context) and decimate by ``DECIMATE``.

    Output frame t covers raw frames [D*t .. D*t+STACK-1]; the tail is
    dropped when fewer than STACK raw frames remain (matches rust).
    """
    t_raw = frames.shape[0]
    n_out = (t_raw - spec.STACK) // spec.DECIMATE + 1
    if n_out <= 0:
        return np.zeros((0, spec.FEAT_DIM), dtype=np.float32)
    out = np.empty((n_out, spec.FEAT_DIM), dtype=np.float32)
    for k in range(spec.STACK):
        cols = frames[
            k : k + (n_out - 1) * spec.DECIMATE + 1 : spec.DECIMATE
        ]
        out[:, k * spec.N_MEL : (k + 1) * spec.N_MEL] = cols
    return out


def features(wave: np.ndarray) -> np.ndarray:
    """Full frontend: log-mel → stack/decimate → global scaling."""
    return stack_frames(log_mel(wave)) * np.float32(spec.FEAT_SCALE)


# ---------------------------------------------------------------------------
# Waveform synthesis
# ---------------------------------------------------------------------------


def synth_phone(
    phone, dur_samples: int, nprng: np.random.Generator
) -> np.ndarray:
    """One phone: 3 formant sinusoids (vibrato, raised-cosine envelope) + noise."""
    t = np.arange(dur_samples, dtype=np.float64) / spec.SAMPLE_RATE
    sig = np.zeros(dur_samples, dtype=np.float64)
    vib = 1.0 + 0.01 * np.sin(2.0 * np.pi * 3.0 * t)
    for f_hz, amp in phone.formants:
        phase = nprng.uniform(0.0, 2.0 * np.pi)
        sig += amp * np.sin(2.0 * np.pi * f_hz * vib * t + phase)
    if not phone.voiced:
        sig *= 0.2
    sig += phone.noise_amp * nprng.standard_normal(dur_samples)
    # Raised-cosine attack/decay over 10 ms.
    edge = min(int(0.010 * spec.SAMPLE_RATE), dur_samples // 2)
    env = np.ones(dur_samples)
    if edge > 0:
        ramp = 0.5 - 0.5 * np.cos(np.pi * np.arange(edge) / edge)
        env[:edge] = ramp
        env[-edge:] = ramp[::-1]
    return (0.3 * sig * env).astype(np.float32)


def synth_utterance(
    words: list, world: World, rng: SplitMix64, nprng: np.random.Generator
):
    """Words → (waveform, phone labels, per-raw-frame phone alignment).

    Returns ``(wave, phones, raw_align)`` where ``raw_align[t]`` is the phone
    id active at raw frame t (0 = silence/pause).
    """
    sil = int(0.050 * spec.SAMPLE_RATE)
    chunks = [np.zeros(sil, dtype=np.float32)]
    align_spans = [(0, sil)]  # (phone id, n samples)
    phones = []
    for wi, w in enumerate(words):
        if wi > 0 and rng.next_f64() < 0.3:
            pause = int(
                (0.020 + 0.040 * rng.next_f64()) * spec.SAMPLE_RATE
            )
            chunks.append(np.zeros(pause, dtype=np.float32))
            align_spans.append((0, pause))
        for pid in world.word_phones(w):
            dur_ms = rng.next_range(spec.PHONE_DUR_MIN_MS, spec.PHONE_DUR_MAX_MS)
            n = int(dur_ms * spec.SAMPLE_RATE / 1000)
            chunks.append(synth_phone(world.phones[pid - 1], n, nprng))
            align_spans.append((pid, n))
            phones.append(pid)
    chunks.append(np.zeros(sil, dtype=np.float32))
    align_spans.append((0, sil))
    wave = np.concatenate(chunks)
    wave += spec.SYNTH_NOISE_FLOOR * nprng.standard_normal(len(wave)).astype(np.float32)

    # Per-raw-frame alignment: phone active at the frame center.
    sample_phone = np.zeros(len(wave), dtype=np.uint32)
    pos = 0
    for pid, n in align_spans:
        sample_phone[pos : pos + n] = pid
        pos += n
    n_frames = 1 + (len(wave) - spec.FRAME_LEN) // spec.FRAME_HOP
    centers = spec.FRAME_HOP * np.arange(max(n_frames, 0)) + spec.FRAME_LEN // 2
    raw_align = sample_phone[np.minimum(centers, len(wave) - 1)]
    return wave, np.asarray(phones, dtype=np.uint32), raw_align


def decimate_align(raw_align: np.ndarray) -> np.ndarray:
    """Raw-frame alignment → output-frame alignment (matches stack_frames)."""
    t_raw = raw_align.shape[0]
    n_out = (t_raw - spec.STACK) // spec.DECIMATE + 1
    if n_out <= 0:
        return np.zeros(0, dtype=np.uint32)
    # label of the first stacked frame (the 'current' frame; rest is context)
    return raw_align[0 : (n_out - 1) * spec.DECIMATE + 1 : spec.DECIMATE]


# ---------------------------------------------------------------------------
# Distortion ('multistyle' training data / 'noisy' eval)
# ---------------------------------------------------------------------------


def colored_noise(n: int, nprng: np.random.Generator) -> np.ndarray:
    """One-pole low-passed white noise (pink-ish)."""
    white = nprng.standard_normal(n).astype(np.float32)
    out = np.empty(n, dtype=np.float32)
    acc = 0.0
    a = 0.85
    for i in range(n):  # small n per utt; fine in numpy loop? vectorize below
        acc = a * acc + (1 - a) * white[i]
        out[i] = acc
    return out


def colored_noise_fast(n: int, nprng: np.random.Generator) -> np.ndarray:
    """Vectorized one-pole filter via FFT-free recursion using lfilter-free
    cumulative trick: y[i] = (1-a) * sum_j a^(i-j) w[j].  Uses a chunked
    scan to stay O(n)."""
    white = nprng.standard_normal(n).astype(np.float64)
    a = 0.85
    y = np.empty(n, dtype=np.float64)
    acc = 0.0
    # Chunked exact recursion (vectorized inner via cumsum in log-space is
    # numerically dicey; chunk size 4096 keeps python overhead negligible).
    step = 4096
    for s in range(0, n, step):
        e = min(s + step, n)
        w = white[s:e] * (1 - a)
        powers = a ** np.arange(1, e - s + 1)
        # y[i] = acc*a^(i+1) + sum_{j<=i} a^(i-j) w[j]
        conv = np.convolve(w, a ** np.arange(e - s))[: e - s]
        y[s:e] = acc * powers + conv
        acc = y[e - 1]
    return y.astype(np.float32)


def babble(n: int, world: World, rng: SplitMix64, nprng) -> np.ndarray:
    """Background babble: superpose 3 random phone streams."""
    out = np.zeros(n, dtype=np.float32)
    for _ in range(3):
        pos = 0
        while pos < n:
            pid = rng.next_range(1, spec.N_PHONES)
            dur = int(
                rng.next_range(spec.PHONE_DUR_MIN_MS, spec.PHONE_DUR_MAX_MS)
                * spec.SAMPLE_RATE / 1000
            )
            seg = synth_phone(world.phones[pid - 1], dur, nprng)
            end = min(pos + dur, n)
            out[pos:end] += seg[: end - pos]
            pos = end
    return out / 3.0


def reverb(wave: np.ndarray, nprng) -> np.ndarray:
    """Cheap exponential-decay reverb (30 ms tail, 3 taps)."""
    taps = [(int(0.011 * spec.SAMPLE_RATE), 0.35),
            (int(0.019 * spec.SAMPLE_RATE), 0.20),
            (int(0.031 * spec.SAMPLE_RATE), 0.10)]
    out = wave.copy()
    for d, g in taps:
        out[d:] += g * wave[:-d]
    return out


def distort(wave, world, rng: SplitMix64, nprng, snr_db_range) -> np.ndarray:
    """Additive colored noise + babble at a sampled SNR, optional reverb."""
    snr_db = snr_db_range[0] + (snr_db_range[1] - snr_db_range[0]) * rng.next_f64()
    if rng.next_f64() < 0.3:
        wave = reverb(wave, nprng)
    mix = 0.5 * colored_noise_fast(len(wave), nprng) + 0.5 * babble(
        len(wave), world, rng, nprng
    )
    p_sig = float(np.mean(wave**2)) + 1e-12
    p_noise = float(np.mean(mix**2)) + 1e-12
    gain = np.sqrt(p_sig / (p_noise * 10.0 ** (snr_db / 10.0)))
    return wave + gain.astype(np.float32) * mix


# ---------------------------------------------------------------------------
# Dataset assembly + .feats format (mirrored by rust/src/io/feat_fmt.rs)
# ---------------------------------------------------------------------------


class Utt:
    __slots__ = ("uid", "feats", "phones", "words", "align")

    def __init__(self, uid, feats, phones, words, align):
        self.uid, self.feats, self.phones, self.words, self.align = (
            uid, feats, phones, words, align,
        )


def gen_utt(uid: int, split_seed: int, world: World, noisy: str) -> Utt:
    """noisy ∈ {'clean', 'noisy', 'multistyle'} (multistyle: 50% distorted)."""
    mix = SplitMix64((split_seed << 20) ^ (uid * 0x9E37))
    seed64 = mix.next_u64()
    rng = SplitMix64(seed64)
    nprng = np.random.default_rng(seed64 & 0x7FFFFFFF)
    words = spec.sample_sentence(rng, world)
    wave, phones, raw_align = synth_utterance(words, world, rng, nprng)
    if noisy == "noisy" or (noisy == "multistyle" and rng.next_f64() < 0.5):
        rng_band = spec.NOISY_SNR_DB if noisy == "noisy" else (10.0, 20.0)
        wave = distort(wave, world, rng, nprng, rng_band)
    f = features(wave)
    align = decimate_align(raw_align)[: f.shape[0]]
    return Utt(uid, f, phones, np.asarray(words, np.uint32), align)


MAGIC = b"FEA1"


def write_feats(path: str, utts: list):
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(struct.pack("<II", 1, len(utts)))  # version, count
        for u in utts:
            t, d = u.feats.shape
            fh.write(
                struct.pack(
                    "<IIIII", u.uid, t, d, len(u.phones), len(u.words)
                )
            )
            fh.write(u.feats.astype("<f4").tobytes())
            fh.write(u.phones.astype("<u4").tobytes())
            fh.write(u.words.astype("<u4").tobytes())
            fh.write(u.align.astype("<u4").tobytes())


def read_feats(path: str) -> list:
    utts = []
    with open(path, "rb") as fh:
        assert fh.read(4) == MAGIC, path
        _ver, n = struct.unpack("<II", fh.read(8))
        for _ in range(n):
            uid, t, d, nu, nw = struct.unpack("<IIIII", fh.read(20))
            feats = np.frombuffer(fh.read(4 * t * d), dtype="<f4").reshape(t, d)
            phones = np.frombuffer(fh.read(4 * nu), dtype="<u4")
            words = np.frombuffer(fh.read(4 * nw), dtype="<u4")
            align = np.frombuffer(fh.read(4 * t), dtype="<u4")
            utts.append(Utt(uid, feats.copy(), phones.copy(), words.copy(), align.copy()))
    return utts


def generate_split(name: str, n: int, seed: int, style: str, world: World):
    return [gen_utt(i, seed, world, style) for i in range(n)]


def export_world(world: World, path: str):
    """Dump the derived world for inspection / rust cross-checks."""
    obj = {
        "phones": [
            {
                "id": p.id,
                "formants": [[f, a] for f, a in p.formants],
                "noise_amp": p.noise_amp,
                "voiced": p.voiced,
            }
            for p in world.phones
        ],
        "lexicon": world.lexicon,
        "bigram": [[[s, w] for s, w in row] for row in world.bigram],
    }
    with open(path, "w") as fh:
        json.dump(obj, fh)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--small", action="store_true",
                    help="tiny splits for CI/tests")
    args = ap.parse_args()
    out = args.out
    os.makedirs(f"{out}/data", exist_ok=True)
    os.makedirs(f"{out}/golden", exist_ok=True)
    world = World()
    export_world(world, f"{out}/world.json")

    n_train = 256 if args.small else spec.N_TRAIN_UTTS
    n_dev = 64 if args.small else spec.N_DEV_UTTS
    n_eval = 64 if args.small else spec.N_EVAL_UTTS

    splits = [
        ("train", n_train, spec.DATA_SEED_TRAIN, "multistyle"),
        ("dev", n_dev, spec.DATA_SEED_DEV, "clean"),
        ("eval_clean", n_eval, spec.DATA_SEED_EVAL, "clean"),
        ("eval_noisy", n_eval, spec.DATA_SEED_EVAL, "noisy"),
    ]
    for name, n, seed, style in splits:
        utts = generate_split(name, n, seed, style, world)
        write_feats(f"{out}/data/{name}.feats", utts)
        frames = sum(u.feats.shape[0] for u in utts)
        print(f"{name}: {n} utts, {frames} frames")

    # Golden frontend pairs for the rust cross-test.
    grng = SplitMix64(0xA0)
    nprng = np.random.default_rng(7)
    for i in range(4):
        words = spec.sample_sentence(grng, world)
        wave, _, _ = synth_utterance(words, world, grng, nprng)
        feat = features(wave)
        wave.astype("<f4").tofile(f"{out}/golden/frontend_{i}.wav.f32")
        feat.astype("<f4").tofile(f"{out}/golden/frontend_{i}.feat.f32")
        with open(f"{out}/golden/frontend_{i}.meta", "w") as fh:
            fh.write(f"{len(wave)} {feat.shape[0]} {feat.shape[1]}\n")
    print("golden frontend pairs written")


if __name__ == "__main__":
    main()
