"""Connectionist Temporal Classification loss (Graves et al. 2006) in JAX.

Log-space forward (alpha) recursion over the blank-extended label sequence
``z = [∅, l₁, ∅, l₂, …, ∅]`` of length 2U+1:

    α_t(s) = logsumexp(α_{t-1}(s), α_{t-1}(s-1), [α_{t-1}(s-2)]) + logP_t(z_s)

where the s-2 skip is allowed only for non-blank z_s with z_s ≠ z_{s-2}.
Loss = −logsumexp(α_T(2U), α_T(2U−1)).

Batched with padding: ``input_lengths`` freezes α past each utterance's end;
``label_lengths`` selects the final states.  Everything is fixed-shape and
scan-based so it jits once per (T, U) bucket.

Tested against brute-force enumeration of all alignments (test_ctc.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _extend_labels(labels: jnp.ndarray, blank: int) -> jnp.ndarray:
    """[B, U] → blank-extended [B, 2U+1]."""
    b, u = labels.shape
    z = jnp.full((b, 2 * u + 1), blank, dtype=labels.dtype)
    return z.at[:, 1::2].set(labels)


def ctc_loss(
    log_probs: jnp.ndarray,      # [B, T, L] log-softmax outputs
    labels: jnp.ndarray,         # [B, U] padded label ids (pad value free)
    input_lengths: jnp.ndarray,  # [B]
    label_lengths: jnp.ndarray,  # [B]
    blank: int = 0,
) -> jnp.ndarray:
    """Per-utterance negative log-likelihood, shape [B]."""
    b, t_max, _ = log_probs.shape
    u_max = labels.shape[1]
    z = _extend_labels(labels, blank)                       # [B, S]
    s_len = 2 * u_max + 1

    # Allowed s-2 skip: z_s non-blank and z_s != z_{s-2}.
    z_shift2 = jnp.concatenate(
        [jnp.full((b, 2), -1, dtype=z.dtype), z[:, :-2]], axis=1
    )
    can_skip = (z != blank) & (z != z_shift2)               # [B, S]

    # Emission log-probs per extended state, per time: gather.
    # emit[t][b, s] = log_probs[b, t, z[b, s]]
    def emit(t):
        return jnp.take_along_axis(log_probs[:, t, :], z, axis=1)

    alpha0 = jnp.full((b, s_len), NEG_INF)
    alpha0 = alpha0.at[:, 0].set(emit(0)[:, 0])
    alpha0 = alpha0.at[:, 1].set(
        jnp.where(label_lengths > 0, emit(0)[:, 1], NEG_INF)
    )

    def lse3(a, b_, c):
        m = jnp.maximum(jnp.maximum(a, b_), c)
        m_safe = jnp.maximum(m, NEG_INF)
        return m_safe + jnp.log(
            jnp.exp(a - m_safe) + jnp.exp(b_ - m_safe) + jnp.exp(c - m_safe)
        )

    def body(alpha, t):
        prev1 = jnp.concatenate(
            [jnp.full((b, 1), NEG_INF), alpha[:, :-1]], axis=1
        )
        prev2 = jnp.concatenate(
            [jnp.full((b, 2), NEG_INF), alpha[:, :-2]], axis=1
        )
        prev2 = jnp.where(can_skip, prev2, NEG_INF)
        new = lse3(alpha, prev1, prev2) + emit(t)
        # Freeze finished utterances (t >= input_length).
        active = (t < input_lengths)[:, None]
        new = jnp.where(active, new, alpha)
        return new, None

    alpha, _ = jax.lax.scan(body, alpha0, jnp.arange(1, t_max))

    # Final: logsumexp over states 2U and 2U-1 (per utterance U).
    idx_last = 2 * label_lengths          # [B]
    idx_prev = jnp.maximum(idx_last - 1, 0)
    a_last = jnp.take_along_axis(alpha, idx_last[:, None], axis=1)[:, 0]
    a_prev = jnp.take_along_axis(alpha, idx_prev[:, None], axis=1)[:, 0]
    a_prev = jnp.where(label_lengths > 0, a_prev, NEG_INF)
    m = jnp.maximum(a_last, a_prev)
    ll = m + jnp.log(jnp.exp(a_last - m) + jnp.exp(a_prev - m))
    return -ll


def ctc_loss_mean(log_probs, labels, input_lengths, label_lengths, blank=0):
    """Mean per-label NLL (normalizes by label length — stabler LR across
    utterance lengths)."""
    nll = ctc_loss(log_probs, labels, input_lengths, label_lengths, blank)
    return jnp.mean(nll / jnp.maximum(label_lengths, 1))


def greedy_decode(log_probs: jnp.ndarray, input_lengths: jnp.ndarray,
                  blank: int = 0):
    """Best-path decode + CTC collapse. Returns [B, T] ids padded with -1.

    (Python-level collapse; used for LER monitoring during training.)
    """
    best = jnp.argmax(log_probs, axis=-1)  # [B, T]
    import numpy as np

    best = np.asarray(best)
    lens = np.asarray(input_lengths)
    out = []
    for i in range(best.shape[0]):
        seq, prev = [], blank
        for t in range(int(lens[i])):
            s = int(best[i, t])
            if s != blank and s != prev:
                seq.append(s)
            prev = s
        out.append(seq)
    return out


def label_error_rate(hyps: list, refs: list) -> float:
    """Σ edit distances / Σ ref lengths (the paper's LER, Figure 2)."""
    total_err, total_len = 0, 0
    for h, r in zip(hyps, refs):
        total_err += edit_distance(h, list(r))
        total_len += len(r)
    return total_err / max(total_len, 1)


def edit_distance(a: list, b: list) -> int:
    """Levenshtein distance (python-side scoring helper)."""
    if not a:
        return len(b)
    if not b:
        return len(a)
    prev = list(range(len(b) + 1))
    for i, x in enumerate(a, 1):
        cur = [i] + [0] * len(b)
        for j, y in enumerate(b, 1):
            cur[j] = min(prev[j] + 1, cur[j - 1] + 1, prev[j - 1] + (x != y))
        prev = cur
    return prev[-1]
