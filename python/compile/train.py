"""Training: float CTC → (QAT) sMBR, with the paper's LR schedules (§5).

Pipeline per architecture (exactly the paper's §5 recipe):

    1. float CTC training                      (§5.1; scheduled projection LR
                                                for models with projection)
    2. float sMBR        → 'match'/'mismatch' baseline model
    3. QAT sMBR (quant)  → 'quant'      (softmax stays float, §6)
    4. QAT sMBR (all)    → 'quant-all'

Learning-rate schedules (paper §5.1/§5.2, time measured in steps here
instead of days — the shape is what matters):

    global      η_g(t) = c_g · 10^(−t / T_g)
    projection  η_p(t) = c_p^(1 − min(t/T_p, 1))     (CTC, 'sched_proj')
                η_p(t) = c_p_smbr (constant)          (sMBR)

Presets:
    --preset quickstart   one small model for artifacts/ + examples
    --preset table1       the 10-architecture grid, all four conditions
    --preset figure2      P-model CTC under {low_lr, svd_init, sched_proj},
                          exporting LER-vs-time curves
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import ctc, data, export, model, smbr, spec
from .model import (FIGURE2_CONFIG, QUICKSTART_CONFIG, TABLE1_CONFIGS, FLOAT,
                    QUANT, QUANT_ALL, ModelConfig)

# ---------------------------------------------------------------------------
# Hyper-parameters (tuned once on the dev split; see EXPERIMENTS.md)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HParams:
    batch_size: int = 32
    warmup_steps: int = 200       # frame-CE alignment warmup (see below)
    ctc_steps: int = 700
    smbr_steps: int = 120
    lr_ctc: float = 0.05          # c_g (CTC)
    lr_decay_steps: float = 3000  # T_g: 10× decay horizon
    lr_smbr: float = 0.004        # c_g (sMBR)
    proj_cp: float = 1e-3         # c_p (scheduled projection LR)
    proj_tp: float = 250.0        # T_p in steps
    proj_cp_smbr: float = 0.5     # c_p^sMBR (constant multiplier)
    momentum: float = 0.9
    clip_norm: float = 5.0
    eval_every: int = 50
    seed: int = 0


def eta_g(t: float, c_g: float, t_g: float) -> float:
    """Global LR: exponential decay (paper §5.1)."""
    return c_g * 10.0 ** (-t / t_g)


def eta_p_sched(t: float, c_p: float, t_p: float) -> float:
    """Scheduled projection LR multiplier: c_p^(1−min(t/T_p,1)) → 1."""
    return c_p ** (1.0 - min(t / t_p, 1.0))


# ---------------------------------------------------------------------------
# Batching
# ---------------------------------------------------------------------------


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def make_batches(utts, batch_size, rng: np.random.Generator, shuffle=True):
    """Length-bucketed padded batches.

    Sorts by frame count, chunks, pads T to a multiple of 16 and U to a
    multiple of 8 (bounds jit-cache variants), then shuffles batch order.
    """
    order = np.argsort([u.feats.shape[0] for u in utts], kind="stable")
    batches = []
    for s in range(0, len(order), batch_size):
        chunk = [utts[i] for i in order[s : s + batch_size]]
        t_max = _round_up(max(u.feats.shape[0] for u in chunk), 16)
        u_max = _round_up(max(len(u.phones) for u in chunk), 8)
        b = len(chunk)
        feats = np.zeros((b, t_max, spec.FEAT_DIM), np.float32)
        labels = np.zeros((b, u_max), np.int32)
        t_len = np.zeros(b, np.int32)
        u_len = np.zeros(b, np.int32)
        align = np.zeros((b, t_max), np.int32)
        for i, u in enumerate(chunk):
            t, _ = u.feats.shape
            feats[i, :t] = u.feats
            labels[i, : len(u.phones)] = u.phones
            t_len[i] = t
            u_len[i] = len(u.phones)
            align[i, :t] = u.align[:t]
        batches.append((feats, labels, t_len, u_len, align))
    if shuffle:
        rng.shuffle(batches)
    return batches


class BatchStream:
    """Endless shuffled epoch stream."""

    def __init__(self, utts, batch_size, seed):
        self.utts = utts
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self._cur = []

    def next(self):
        if not self._cur:
            self._cur = make_batches(self.utts, self.batch_size, self.rng)
        return self._cur.pop()


# ---------------------------------------------------------------------------
# Optimizer: momentum SGD + global-norm clipping
# ---------------------------------------------------------------------------


def sgd_init(params):
    return jax.tree.map(jnp.zeros_like, params)


@functools.partial(jax.jit, static_argnames=())
def _clip_by_global_norm(grads, max_norm):
    norm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def sgd_update(params, vel, grads, lr_tree, momentum, clip):
    """Per-parameter learning rates via ``lr_tree`` (projection multiplier)."""
    grads, gnorm = _clip_by_global_norm(grads, clip)

    new_vel = jax.tree.map(
        lambda v, g: momentum * v + g, vel, grads
    )
    new_params = jax.tree.map(
        lambda p, v, lr: p - lr * v, params, new_vel, lr_tree
    )
    return new_params, new_vel, gnorm


def lr_tree_for(params, base_lr, proj_mult):
    """Projection matrices (``l*.wp``) get ``base_lr * proj_mult``."""
    return {
        k: jnp.asarray(
            base_lr * (proj_mult if k.endswith(".wp") else 1.0), jnp.float32
        )
        for k in params
    }


# ---------------------------------------------------------------------------
# Train steps (jitted factories per (cfg, mode))
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def ctc_step_fn(cfg: ModelConfig, mode: str):
    @jax.jit
    def step(params, vel, feats, labels, t_len, u_len, lr_base, lr_proj,
             momentum, clip):
        def loss_fn(p):
            lp = model.log_posteriors(p, cfg, feats, mode)
            return ctc.ctc_loss_mean(lp, labels, t_len, u_len)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr_tree = {
            k: lr_base * lr_proj if k.endswith(".wp") else lr_base
            for k in params
        }
        params, vel, gnorm = sgd_update(
            params, vel, grads, lr_tree, momentum, clip
        )
        return params, vel, loss, gnorm

    return step


@functools.lru_cache(maxsize=64)
def ce_step_fn(cfg: ModelConfig):
    """Frame-CE warmup step on the forced alignment.

    The paper constrains CTC alignments to within 100 ms of a forced
    alignment (§4) to stabilize training; with the synthetic world we have
    the exact alignment, so the equivalent stabilizer is a short frame-level
    cross-entropy warmup before the CTC stage (without it, small models at
    this data scale stick in the all-blank CTC plateau)."""

    @jax.jit
    def step(params, vel, feats, align, t_len, lr_base, lr_proj, momentum,
             clip):
        t = feats.shape[1]
        mask = (jnp.arange(t)[None, :] < t_len[:, None]).astype(jnp.float32)

        def loss_fn(p):
            lp = model.log_posteriors(p, cfg, feats, FLOAT)
            nll = -jnp.take_along_axis(lp, align[..., None], -1)[..., 0]
            return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr_tree = {
            k: lr_base * lr_proj if k.endswith(".wp") else lr_base
            for k in params
        }
        params, vel, gnorm = sgd_update(
            params, vel, grads, lr_tree, momentum, clip
        )
        return params, vel, loss, gnorm

    return step


@functools.lru_cache(maxsize=64)
def smbr_step_fn(cfg: ModelConfig, mode: str):
    @jax.jit
    def step(key, params, vel, feats, labels, t_len, u_len, lr_base, lr_proj,
             momentum, clip):
        def loss_fn(p):
            lp = model.log_posteriors(p, cfg, feats, mode)
            risk, min_risk = smbr.smbr_risk(key, lp, labels, t_len, u_len)
            # small CTC anchor keeps paths from degenerating (standard MWER
            # practice; analogous to the paper's CE smoothing in sMBR).
            anchor = ctc.ctc_loss_mean(lp, labels, t_len, u_len)
            return risk + 0.1 * anchor, min_risk

        (loss, min_risk), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params
        )
        lr_tree = {
            k: lr_base * lr_proj if k.endswith(".wp") else lr_base
            for k in params
        }
        params, vel, gnorm = sgd_update(
            params, vel, grads, lr_tree, momentum, clip
        )
        return params, vel, loss, min_risk

    return step


# ---------------------------------------------------------------------------
# Evaluation (dev LER, used for curves + early sanity)
# ---------------------------------------------------------------------------


def dev_ler(params, cfg, dev_batches, mode=FLOAT) -> float:
    hyps_all, refs_all = [], []
    fwd = functools.partial(model.log_posteriors, params, cfg, mode=mode)
    for feats, labels, t_len, u_len, _align in dev_batches:
        lp = jax.jit(fwd)(jnp.asarray(feats))
        hyps = ctc.greedy_decode(lp, t_len)
        for i in range(len(hyps)):
            hyps_all.append(hyps[i])
            refs_all.append(list(labels[i, : u_len[i]]))
    return ctc.label_error_rate(hyps_all, refs_all)


# ---------------------------------------------------------------------------
# Stage drivers
# ---------------------------------------------------------------------------


def train_ctc(
    cfg: ModelConfig,
    train_utts,
    dev_batches,
    hp: HParams,
    schedule: str = "sched_proj",   # sched_proj | low_lr | none
    init: dict | None = None,
    time_offset: float = 0.0,
    log=print,
):
    """Float CTC training.  Returns (params, curve[(wall_s, step, ler)])."""
    params = init if init is not None else model.init_params(
        cfg, jax.random.PRNGKey(hp.seed)
    )
    vel = sgd_init(params)
    stream = BatchStream(train_utts, hp.batch_size, hp.seed + 1)
    step_fn = ctc_step_fn(cfg, FLOAT)
    warm_fn = ce_step_fn(cfg)
    curve = []
    c_g = hp.lr_ctc * (0.01 if schedule == "low_lr" else 1.0)
    t0 = time.time()

    def lr_pm(it):
        lr = eta_g(it, c_g, hp.lr_decay_steps)
        if schedule == "sched_proj" and cfg.proj_dim is not None:
            pm = eta_p_sched(it, hp.proj_cp, hp.proj_tp)
        else:
            pm = 1.0
        return lr, pm

    # Phase 0: frame-CE alignment warmup (see ce_step_fn docstring); the
    # global/projection schedules apply across warmup+CTC with a shared
    # step clock, so Figure-2 comparisons include warmup time.
    for it in range(hp.warmup_steps):
        lr, pm = lr_pm(it)
        feats, labels, t_len, u_len, align = stream.next()
        params, vel, loss, _ = warm_fn(
            params, vel, jnp.asarray(feats), jnp.asarray(align),
            jnp.asarray(t_len),
            jnp.asarray(lr, jnp.float32), jnp.asarray(pm, jnp.float32),
            hp.momentum, hp.clip_norm,
        )
        if (it + 1) % hp.eval_every == 0 or it == 0:
            ler = dev_ler(params, cfg, dev_batches)
            curve.append((time.time() - t0 + time_offset, it + 1, ler))
            log(
                f"  [{cfg.name}/{schedule}] warmup {it+1:4d} "
                f"ce {float(loss):6.3f} dev-LER {ler:.3f}"
            )

    for it0 in range(hp.ctc_steps):
        it = it0 + hp.warmup_steps
        lr, pm = lr_pm(it)
        feats, labels, t_len, u_len, _align = stream.next()
        params, vel, loss, gnorm = step_fn(
            params, vel, jnp.asarray(feats), jnp.asarray(labels),
            jnp.asarray(t_len), jnp.asarray(u_len),
            jnp.asarray(lr, jnp.float32), jnp.asarray(pm, jnp.float32),
            hp.momentum, hp.clip_norm,
        )
        if not np.isfinite(float(loss)):
            log(f"  [{cfg.name}] DIVERGED at step {it} (loss={float(loss)})")
            curve.append((time.time() - t0 + time_offset, it, 1.0))
            break
        if (it + 1) % hp.eval_every == 0 or it0 == 0:
            ler = dev_ler(params, cfg, dev_batches)
            curve.append((time.time() - t0 + time_offset, it + 1, ler))
            log(
                f"  [{cfg.name}/{schedule}] step {it+1:4d} "
                f"loss {float(loss):6.3f} lr {lr:.2e} pm {pm:.2e} "
                f"dev-LER {ler:.3f}"
            )
    return params, curve


def train_smbr(
    cfg: ModelConfig,
    params: dict,
    train_utts,
    dev_batches,
    hp: HParams,
    mode: str,
    log=print,
):
    """sMBR stage; ``mode`` ∈ {float, quant, quant_all} — quant modes are the
    paper's quantization-aware training (§3.2/§5.2)."""
    params = dict(params)
    vel = sgd_init(params)
    stream = BatchStream(train_utts, hp.batch_size, hp.seed + 2)
    step_fn = smbr_step_fn(cfg, mode)
    key = jax.random.PRNGKey(hp.seed + 3)
    pm = hp.proj_cp_smbr if cfg.proj_dim is not None else 1.0
    for it in range(hp.smbr_steps):
        lr = eta_g(it, hp.lr_smbr, hp.lr_decay_steps)
        key, sub = jax.random.split(key)
        feats, labels, t_len, u_len, _align = stream.next()
        params, vel, loss, min_risk = step_fn(
            sub, params, vel, jnp.asarray(feats), jnp.asarray(labels),
            jnp.asarray(t_len), jnp.asarray(u_len),
            jnp.asarray(lr, jnp.float32), jnp.asarray(pm, jnp.float32),
            hp.momentum, hp.clip_norm,
        )
        if (it + 1) % hp.eval_every == 0 or it == 0:
            ler = dev_ler(params, cfg, dev_batches, mode=mode)
            log(
                f"  [{cfg.name}/smbr-{mode}] step {it+1:4d} "
                f"risk {float(loss):6.3f} dev-LER({mode}) {ler:.3f}"
            )
    return params


def train_all_conditions(cfg, train_utts, dev_batches, hp, out_dir, log=print):
    """Full paper recipe for one architecture; exports the 3 model files."""
    log(f"[{cfg.name}] CTC float training ({cfg.param_count()} params)")
    sched = "sched_proj" if cfg.proj_dim is not None else "none"
    ctc_params, _ = train_ctc(cfg, train_utts, dev_batches, hp, sched, log=log)

    log(f"[{cfg.name}] sMBR float (match/mismatch baseline)")
    float_params = train_smbr(
        cfg, ctc_params, train_utts, dev_batches, hp, FLOAT, log=log
    )
    export.write_qam(
        f"{out_dir}/{cfg.name}.float.qam", float_params, cfg, quantized=False
    )
    log(f"[{cfg.name}] QAT sMBR (quant: softmax stays float)")
    qat = train_smbr(
        cfg, ctc_params, train_utts, dev_batches, hp, QUANT, log=log
    )
    export.write_qam(
        f"{out_dir}/{cfg.name}.qat.qam", qat, cfg,
        quantized=True, quantize_output=False,
    )
    log(f"[{cfg.name}] QAT sMBR (quant-all)")
    qat_all = train_smbr(
        cfg, ctc_params, train_utts, dev_batches, hp, QUANT_ALL, log=log
    )
    export.write_qam(
        f"{out_dir}/{cfg.name}.qatall.qam", qat_all, cfg,
        quantized=True, quantize_output=True,
    )


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------


def load_data(art: str):
    train_utts = data.read_feats(f"{art}/data/train.feats")
    dev_utts = data.read_feats(f"{art}/data/dev.feats")
    dev_batches = make_batches(
        dev_utts, 32, np.random.default_rng(0), shuffle=False
    )
    return train_utts, dev_batches


def preset_quickstart(art: str, hp: HParams):
    train_utts, dev_batches = load_data(art)
    os.makedirs(f"{art}/models", exist_ok=True)
    train_all_conditions(
        QUICKSTART_CONFIG, train_utts, dev_batches, hp, f"{art}/models"
    )


def preset_table1(art: str, hp: HParams, arch: str | None = None):
    """Train the grid.  ``arch`` filters to one architecture — the Makefile
    drives one python process per arch (a long-lived process accumulating
    dozens of jitted executables can hit XLA-CPU's JIT dylib limits)."""
    train_utts, dev_batches = load_data(art)
    os.makedirs(f"{art}/models", exist_ok=True)
    for cfg in TABLE1_CONFIGS:
        if arch is not None and cfg.name != arch:
            continue
        if arch is None and os.path.exists(
            f"{art}/models/{cfg.name}.qatall.qam"
        ):
            print(f"[{cfg.name}] already trained — skip")
            continue
        train_all_conditions(cfg, train_utts, dev_batches, hp, f"{art}/models")


def preset_qat_bits(art: str, hp: HParams, bits: int = 4):
    """Extension: QAT at reduced bit width (DESIGN.md E5-QAT).

    Starts from the float sMBR quickstart model, runs quantization-aware
    sMBR with ``quant<bits>`` numerics, and exports
    ``<name>.qat<bits>.qam``.  Together with `quantasr ablate-bits` this
    shows QAT recovering the post-training loss at the bit widths where it
    is unambiguous (4 bits), amplifying the paper's §3.2 result.
    """
    train_utts, dev_batches = load_data(art)
    cfg = QUICKSTART_CONFIG
    header, params, _ = export.read_qam(f"{art}/models/{cfg.name}.float.qam")
    params = {k: jnp.asarray(v) for k, v in params.items()}
    mode = f"quant{bits}"
    hp = dataclasses.replace(hp, smbr_steps=max(hp.smbr_steps, 200))
    qat = train_smbr(cfg, params, train_utts, dev_batches, hp, mode)
    export.write_qam(
        f"{art}/models/{cfg.name}.qat{bits}.qam", qat, cfg,
        quantized=True, quantize_output=False, bits=bits,
    )
    print(f"wrote {cfg.name}.qat{bits}.qam")


def preset_figure2(art: str, hp: HParams):
    """The §5.1 schedule comparison on the P-model (paper's P=200 analog)."""
    train_utts, dev_batches = load_data(art)
    os.makedirs(f"{art}/curves", exist_ok=True)
    cfg = FIGURE2_CONFIG
    curves = {}

    # (a) Low global LR, no multiplier.
    _, curves["low_lr"] = train_ctc(
        cfg, train_utts, dev_batches, hp, schedule="low_lr"
    )
    # (b) SVD initialization: pre-train the uncompressed model, factor, then
    #     train the projection model (two-stage; time includes stage 1).
    cfg_unc = ModelConfig(cfg.num_layers, cfg.cell_dim)
    hp_pre = dataclasses.replace(hp, ctc_steps=hp.ctc_steps // 2)
    t0 = time.time()
    unc_params, _ = train_ctc(
        cfg_unc, train_utts, dev_batches, hp_pre, schedule="none"
    )
    pre_time = time.time() - t0
    svd_params = model.svd_init_from_uncompressed(unc_params, cfg_unc, cfg)
    _, curves["svd_init"] = train_ctc(
        cfg, train_utts, dev_batches, hp, schedule="none",
        init=svd_params, time_offset=pre_time,
    )
    # (c) Scheduled projection LR (the paper's proposal).
    _, curves["sched_proj"] = train_ctc(
        cfg, train_utts, dev_batches, hp, schedule="sched_proj"
    )

    for name, curve in curves.items():
        with open(f"{art}/curves/figure2_{name}.csv", "w") as fh:
            fh.write("wall_seconds,step,dev_ler\n")
            for wall, it, ler in curve:
                fh.write(f"{wall:.2f},{it},{ler:.4f}\n")
    print("figure2 curves written")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", required=True,
                    choices=["quickstart", "table1", "figure2", "qat_bits"])
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--arch", default=None,
                    help="table1: train only this architecture")
    ap.add_argument("--ctc-steps", type=int, default=None)
    ap.add_argument("--smbr-steps", type=int, default=None)
    args = ap.parse_args()
    hp = HParams()
    if args.ctc_steps is not None:
        hp = dataclasses.replace(hp, ctc_steps=args.ctc_steps)
    if args.smbr_steps is not None:
        hp = dataclasses.replace(hp, smbr_steps=args.smbr_steps)
    t0 = time.time()
    if args.preset == "table1":
        preset_table1(args.out, hp, arch=args.arch)
    elif args.preset == "qat_bits":
        preset_qat_bits(args.out, hp, bits=args.bits)
    else:
        {"quickstart": preset_quickstart,
         "figure2": preset_figure2}[args.preset](args.out, hp)
    print(f"preset {args.preset} done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
