"""Approximate sMBR sequence-discriminative training (paper §5.2).

The paper sequence-trains CTC models with lattice-based state-level minimum
Bayes risk.  Lattices require the production decoder; we substitute an
N-best/sampled **minimum expected label-error risk** (MWER-style; DESIGN.md
§2), which preserves what matters for this paper: a *second*,
sequence-discriminative training stage in which quantization-aware forward
passes run (§3.2) and full-precision gradients update master weights.

Risk:
    paths k ~ per-frame categorical(log_probs / τ)   (+ the greedy path)
    r_k   = editdist(collapse(path_k), ref) / |ref|
    L     = Σ_k softmax(logP(path_k))·(r_k − r̄)      (baseline-subtracted)

The edit distance runs as a fixed-shape DP inside jit (no host callback);
gradients flow only through the path log-probabilities, as in MWER.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

BLANK = 0
BIG = 1e9


def collapse_paths(paths: jnp.ndarray, input_lengths: jnp.ndarray):
    """CTC-collapse frame paths [K, B, T] → padded labels + lengths.

    Keeps positions where ``p_t != blank and p_t != p_{t-1}`` (and t within
    the utterance).  Returns (labels [K, B, T] padded with 0, lengths).
    Fixed-shape: uses a stable scatter by cumulative-count.
    """
    k, b, t = paths.shape
    prev = jnp.concatenate(
        [jnp.full((k, b, 1), -1, paths.dtype), paths[:, :, :-1]], axis=2
    )
    valid = (
        (paths != BLANK)
        & (paths != prev)
        & (jnp.arange(t)[None, None, :] < input_lengths[None, :, None])
    )
    # position of each kept symbol in the output
    pos = jnp.cumsum(valid, axis=2) - 1
    pos = jnp.where(valid, pos, t - 1)  # dump invalid into last slot
    out = jnp.zeros((k, b, t), paths.dtype)
    out = jax.vmap(
        jax.vmap(lambda o, p, v, x: o.at[p].add(jnp.where(v, x, 0)))
    )(out, pos, valid, paths)
    # Note: two symbols can't collide on a slot because pos is strictly
    # increasing over kept symbols; invalid symbols add 0 to the dump slot —
    # mask the dump slot explicitly when it wasn't legitimately assigned.
    lengths = jnp.sum(valid, axis=2)
    slot_ok = jnp.arange(t)[None, None, :] < lengths[:, :, None]
    out = jnp.where(slot_ok, out, 0)
    return out, lengths


def edit_distance_padded(a, la, b_, lb):
    """Levenshtein DP over padded sequences a [Ta], b [Tb] (scalar lengths).

    Fixed-shape scan over rows of the DP table; entries beyond (la, lb) are
    neutralized so the result is exact for the true lengths.
    """
    ta = a.shape[0]
    tb = b_.shape[0]
    row0 = jnp.minimum(jnp.arange(tb + 1, dtype=jnp.float32), lb.astype(jnp.float32) + 0 * jnp.arange(tb + 1))
    row0 = jnp.arange(tb + 1, dtype=jnp.float32)

    def body(row, i):
        # computing DP row i (1-based) against symbol a[i-1]
        sym = a[i - 1]
        sub_cost = jnp.where(b_ == sym, 0.0, 1.0)  # [Tb]

        def inner(carry, j):
            left = carry
            diag = row[j - 1]
            up = row[j]
            val = jnp.minimum(
                jnp.minimum(left + 1.0, up + 1.0), diag + sub_cost[j - 1]
            )
            return val, val

        first = row[0] + 1.0
        _, rest = jax.lax.scan(inner, first, jnp.arange(1, tb + 1))
        new_row = jnp.concatenate([first[None], rest])
        # rows beyond la: keep previous (frozen)
        return jnp.where(i <= la, new_row, row), None

    row, _ = jax.lax.scan(body, row0, jnp.arange(1, ta + 1))
    return row[lb.astype(jnp.int32)]


def _sample_paths(key, log_probs, k_samples, temperature):
    """Gumbel-max sampling of K frame paths from [B, T, L] posteriors."""
    noise = jax.random.gumbel(
        key, (k_samples,) + log_probs.shape, log_probs.dtype
    )
    return jnp.argmax(log_probs[None] / temperature + noise, axis=-1)


@functools.partial(jax.jit, static_argnames=("k_samples",))
def smbr_risk(
    key: jax.Array,
    log_probs: jnp.ndarray,      # [B, T, L]
    labels: jnp.ndarray,         # [B, U]
    input_lengths: jnp.ndarray,  # [B]
    label_lengths: jnp.ndarray,  # [B]
    k_samples: int = 4,
    temperature: float = 1.0,
):
    """Expected normalized label-error risk; scalar loss."""
    b, t, _ = log_probs.shape
    sampled = _sample_paths(key, log_probs, k_samples, temperature)  # [K,B,T]
    greedy = jnp.argmax(log_probs, axis=-1)[None]                    # [1,B,T]
    paths = jnp.concatenate([greedy, sampled], axis=0)               # [K+1,B,T]
    k = paths.shape[0]

    # Path log-probabilities (sum over valid frames).
    lp_frames = jnp.take_along_axis(
        jnp.broadcast_to(log_probs[None], (k,) + log_probs.shape),
        paths[..., None],
        axis=-1,
    )[..., 0]                                                        # [K,B,T]
    t_mask = jnp.arange(t)[None, None, :] < input_lengths[None, :, None]
    path_lp = jnp.sum(jnp.where(t_mask, lp_frames, 0.0), axis=2)     # [K,B]

    hyps, hyp_lens = collapse_paths(paths, input_lengths)            # [K,B,T]

    risk = jax.vmap(
        jax.vmap(edit_distance_padded, in_axes=(0, 0, 0, 0)),
        in_axes=(0, 0, None, None),
    )(hyps, hyp_lens.astype(jnp.float32), labels,
      label_lengths.astype(jnp.float32))                             # [K,B]
    risk = risk / jnp.maximum(label_lengths[None].astype(jnp.float32), 1.0)
    risk = jax.lax.stop_gradient(risk)

    w = jax.nn.softmax(path_lp, axis=0)                              # [K,B]
    baseline = jnp.mean(risk, axis=0, keepdims=True)
    loss = jnp.sum(w * (risk - baseline), axis=0)                    # [B]
    return jnp.mean(loss), jnp.mean(jnp.min(risk, axis=0))
