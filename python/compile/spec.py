"""Shared world/frontend/model specification.

Single source of truth for every constant that the Rust side
(``rust/src/sim``, ``rust/src/frontend``) mirrors.  Anything changed here must
be changed there; the golden tests (``python/tests/test_golden.py`` emitting
``artifacts/golden/*`` consumed by ``rust/tests/golden_frontend.rs``) catch
drift between the two implementations.

The synthetic speech world replaces the paper's proprietary Google
voice-search/dictation corpora (see DESIGN.md §2): a 40-phone inventory with
formant-like spectra, a 200-word lexicon, and a bigram sentence generator.
The derived quantities that MUST be bit-identical between python and rust
(phone formants, lexicon, bigram table) are generated from the shared
SplitMix64 PRNG below; bulk float noise only has to be distributionally
identical.
"""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Audio / frontend (paper §4: 40-d log-mel, 8kHz, 25ms/10ms, stack 8 skip 3;
# here scaled to 16 mel, stack 4 skip 2 — same pipeline, laptop-sized).
# ---------------------------------------------------------------------------
SAMPLE_RATE = 8000
FRAME_LEN = 200          # 25 ms
FRAME_HOP = 80           # 10 ms
FFT_SIZE = 256
N_MEL = 16
MEL_FMIN = 125.0
MEL_FMAX = 3800.0
PREEMPHASIS = 0.97
LOG_FLOOR = 1e-7

STACK = 4                # frames stacked (3 right context)
DECIMATE = 2             # present every 2nd stacked frame
FEAT_DIM = N_MEL * STACK  # 64
FEAT_SCALE = 1.0 / 3.0   # global feature scaling → unit-ish variance
                         # (applied in data.py and rust frontend identically)

# ---------------------------------------------------------------------------
# Phone inventory / lexicon / text
# ---------------------------------------------------------------------------
N_PHONES = 40            # phone ids 1..40; 0 is the CTC blank
BLANK = 0
N_LABELS = N_PHONES + 1  # network output dimension

N_WORDS = 200            # lexicon size
WORD_MIN_PHONES = 2
WORD_MAX_PHONES = 6
SENT_MIN_WORDS = 1
SENT_MAX_WORDS = 4

# Phone duration range in milliseconds.
PHONE_DUR_MIN_MS = 40
PHONE_DUR_MAX_MS = 100

# Master seed for the world (lexicon, phones, bigram LM).
WORLD_SEED = 0x5EED_2016

# Dataset sizes (train scaled for laptop CTC training).
N_TRAIN_UTTS = 4096
N_DEV_UTTS = 256
N_EVAL_UTTS = 4096
DATA_SEED_TRAIN = 101
DATA_SEED_DEV = 202
DATA_SEED_EVAL = 303
NOISY_SNR_DB = (0.0, 10.0)   # uniform range for the 'noisy' eval condition
SYNTH_NOISE_FLOOR = 0.02     # white-noise floor added to every waveform

# ---------------------------------------------------------------------------
# Quantization (paper §3)
# ---------------------------------------------------------------------------
QUANT_BITS = 8
QUANT_SCALE = (1 << QUANT_BITS) - 1  # S = 255


# ---------------------------------------------------------------------------
# SplitMix64 — shared deterministic PRNG (mirrored in rust/src/sim/rng.rs)
# ---------------------------------------------------------------------------
_MASK = (1 << 64) - 1


class SplitMix64:
    """SplitMix64 PRNG; bit-identical to ``rust/src/sim/rng.rs``."""

    def __init__(self, seed: int):
        self.state = seed & _MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & _MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
        return (z ^ (z >> 31)) & _MASK

    def next_f64(self) -> float:
        """Uniform in [0, 1) with 53 bits of precision (same as rust)."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_range(self, lo: int, hi: int) -> int:
        """Uniform integer in [lo, hi] (inclusive); hi > lo required."""
        span = hi - lo + 1
        return lo + self.next_u64() % span


# ---------------------------------------------------------------------------
# World derivation (phones, lexicon, bigram) — bit-identical across languages
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Phone:
    """Formant-like description of a synthetic phone.

    ``formants`` are three (freq_hz, amplitude) pairs; ``noise_amp`` adds a
    fricative-like white-noise component; ``voiced`` gates the harmonic part.
    """

    id: int
    formants: list  # [(f_hz, amp)] * 3
    noise_amp: float
    voiced: bool


def derive_phones(rng: SplitMix64) -> list:
    """Derive the 40-phone inventory. Consumes exactly 8 draws per phone."""
    phones = []
    for pid in range(1, N_PHONES + 1):
        f1 = 220.0 + 1000.0 * rng.next_f64()
        f2 = f1 + 300.0 + 1200.0 * rng.next_f64()
        f3 = f2 + 400.0 + 1000.0 * rng.next_f64()
        a1 = 0.5 + 0.5 * rng.next_f64()
        a2 = 0.25 + 0.45 * rng.next_f64()
        a3 = 0.1 + 0.3 * rng.next_f64()
        noise = 0.02 + 0.1 * rng.next_f64()
        voiced_draw = rng.next_f64()
        voiced = voiced_draw > 0.25  # ~25% unvoiced/fricative-like
        if not voiced:
            noise += 0.35
        # Clamp formants under Nyquist with margin.
        f3 = min(f3, 3600.0)
        f2 = min(f2, f3 - 100.0)
        phones.append(
            Phone(pid, [(f1, a1), (f2, a2), (f3, a3)], noise, voiced)
        )
    return phones


def derive_lexicon(rng: SplitMix64) -> list:
    """200 words, each a phone sequence of length 2..6.

    Consumes 1 + len draws per word. Rejects duplicate pronunciations by
    re-drawing the final phone (deterministic, mirrored in rust).
    """
    seen = set()
    lex = []
    for _w in range(N_WORDS):
        n = rng.next_range(WORD_MIN_PHONES, WORD_MAX_PHONES)
        seq = [rng.next_range(1, N_PHONES) for _ in range(n)]
        while tuple(seq) in seen:
            seq[-1] = rng.next_range(1, N_PHONES)
        seen.add(tuple(seq))
        lex.append(seq)
    return lex


def derive_bigram(rng: SplitMix64) -> list:
    """Sparse bigram successor table: for each word, 8 (successor, weight).

    Sentence generation picks from these with prob 0.8, otherwise from the
    Zipf-ish unigram (rank-based) distribution.  Returned as a list of lists
    of (word_id, weight) with weights summing to 1 per row.
    """
    table = []
    for _w in range(N_WORDS):
        succ = []
        total = 0.0
        for _k in range(8):
            s = rng.next_range(0, N_WORDS - 1)
            wgt = 0.1 + rng.next_f64()
            succ.append([s, wgt])
            total += wgt
        for e in succ:
            e[1] /= total
        table.append([(s, w) for s, w in succ])
    return table


class World:
    """The full derived synthetic world (phones + lexicon + bigram)."""

    def __init__(self, seed: int = WORLD_SEED):
        # Independent streams so adding draws to one stage cannot shift
        # another (rust mirrors the same three sub-seeds).
        self.phones = derive_phones(SplitMix64(seed ^ 0x01))
        self.lexicon = derive_lexicon(SplitMix64(seed ^ 0x02))
        self.bigram = derive_bigram(SplitMix64(seed ^ 0x03))

    def word_phones(self, word_id: int) -> list:
        return self.lexicon[word_id]


def zipf_word(rng: SplitMix64) -> int:
    """Zipf-ish unigram draw over word ids (rank = id)."""
    # Inverse-CDF over 1/(rank+1) weights, computed incrementally and
    # identically in rust (harmonic normalization constant H).
    h = _HARMONIC
    u = rng.next_f64() * h
    acc = 0.0
    for w in range(N_WORDS):
        acc += 1.0 / (w + 1.0)
        if u <= acc:
            return w
    return N_WORDS - 1


_HARMONIC = sum(1.0 / (w + 1.0) for w in range(N_WORDS))


def sample_sentence(rng: SplitMix64, world: World) -> list:
    """Sample a word-id sentence from the bigram/unigram mixture."""
    n = rng.next_range(SENT_MIN_WORDS, SENT_MAX_WORDS)
    words = [zipf_word(rng)]
    while len(words) < n:
        use_bigram = rng.next_f64() < 0.8
        if use_bigram:
            row = world.bigram[words[-1]]
            u = rng.next_f64()
            acc = 0.0
            nxt = row[-1][0]
            for s, wgt in row:
                acc += wgt
                if u <= acc:
                    nxt = s
                    break
            words.append(nxt)
        else:
            words.append(zipf_word(rng))
    return words
