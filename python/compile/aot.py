"""AOT lowering: model step functions → HLO *text* artifacts for rust/PJRT.

For each exported model we lower single-timestep inference functions with
the weights baked in as constants, at a set of batch sizes:

    artifacts/hlo/<model>.<variant>.b<B>.hlo.txt
    artifacts/hlo/<model>.<variant>.b<B>.json     (I/O manifest for rust)

Variants:
    float        — f32 graph from the float model ('match' numerics)
    quant        — §3.1 integer pipeline (quantize → int32 dot → recover)
                   built from the stored u8 weights, pure-jnp ops
    quant_pallas — same numerics but the gate/output matmuls go through the
                   L1 Pallas kernel (interpret=True) so the Figure-1 fused
                   kernel itself is what lowers into the HLO

Interchange is HLO **text**, not serialized protos: jax ≥ 0.5 emits 64-bit
instruction ids that the image's xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Step signature (row-major f32 unless noted):
    inputs : x [B, input_dim], then per layer l: c_l [B, N], h_l [B, rec]
    outputs: log_probs [B, num_labels], then per layer: c_l', h_l'
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import export, model, quantlib, spec
from .kernels import qmatmul as qmk
from .quantlib import QParams

FLOAT = "float"
QUANT = "quant"
QUANT_PALLAS = "quant_pallas"


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring).

    ``print_large_constants=True`` is essential: the baked weight matrices
    must survive the text round-trip (the default elides them as ``{...}``).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


# ---------------------------------------------------------------------------
# Inference-step builders (weights closed over as constants)
# ---------------------------------------------------------------------------


def _mk_mm(records: dict, name: str, variant: str):
    """Matmul closure for one weight matrix under the chosen variant."""
    dtype, arr, vmin, q = records[name]
    if dtype == export.F32 or variant == FLOAT:
        if dtype == export.F32:
            w = jnp.asarray(arr, jnp.float32)
        else:  # recover stored u8 to float (float graph of a quant model)
            zp = round(q * vmin)
            w = (jnp.asarray(arr, jnp.float32) + zp) / q
        return lambda x: x @ w
    # quantized: stored u8 weights enter the integer pipeline directly
    wq = jnp.asarray(arr, jnp.float32)          # V' values
    zp = float(round(q * vmin))
    wp = QParams(
        q=jnp.asarray(q, jnp.float32),
        zp=jnp.asarray(zp, jnp.float32),
        vmin=jnp.asarray(vmin, jnp.float32),
    )
    if variant == QUANT:
        return lambda x: quantlib.quantized_matmul_q(x, wq, wp)

    # QUANT_PALLAS: the L1 kernel (bias/activation stay outside: the LSTM
    # gate math needs the raw pre-activations of two matmuls summed).
    zeros_b = jnp.zeros((arr.shape[1],), jnp.float32)

    def mm(x):
        xp = quantlib.compute_qparams(x)
        return qmk.qmatmul(
            x, wq, zeros_b, xp.q, xp.zp, wp.q, wp.zp, activation="none",
        )

    return mm


def build_step(header: dict, records: dict, variant: str):
    """Returns (step_fn, cfg).  step_fn(x, *state) → (log_probs, *state')."""
    cfg = export.config_from_header(header)
    quantize_output = header.get("quantize_output", False)

    mms = {}
    for l in range(cfg.num_layers):
        mms[f"l{l}.wx"] = _mk_mm(records, f"l{l}.wx", variant)
        mms[f"l{l}.wh"] = _mk_mm(records, f"l{l}.wh", variant)
        if cfg.proj_dim is not None:
            mms[f"l{l}.wp"] = _mk_mm(records, f"l{l}.wp", variant)
    out_variant = variant if quantize_output else FLOAT
    mms["out.w"] = _mk_mm(records, "out.w", out_variant)
    biases = {
        k: jnp.asarray(v[1], jnp.float32)
        for k, v in records.items()
        if k.endswith(".b") or k == "out.b"
    }

    def step(x, *state):
        h_in = x
        new_state = []
        for l in range(cfg.num_layers):
            c_prev = state[2 * l]
            h_prev = state[2 * l + 1]
            gates = (
                mms[f"l{l}.wx"](h_in)
                + mms[f"l{l}.wh"](h_prev)
                + biases[f"l{l}.b"]
            )
            n = cfg.cell_dim
            i_g = jax.nn.sigmoid(gates[:, 0 * n:1 * n])
            f_g = jax.nn.sigmoid(gates[:, 1 * n:2 * n])
            g_g = jnp.tanh(gates[:, 2 * n:3 * n])
            o_g = jax.nn.sigmoid(gates[:, 3 * n:4 * n])
            c_new = f_g * c_prev + i_g * g_g
            h_new = o_g * jnp.tanh(c_new)
            if cfg.proj_dim is not None:
                h_new = mms[f"l{l}.wp"](h_new)
            new_state += [c_new, h_new]
            h_in = h_new
        logits = mms["out.w"](h_in) + biases["out.b"]
        log_probs = jax.nn.log_softmax(logits, axis=-1)
        return (log_probs, *new_state)

    return step, cfg


def lower_model(qam_path: str, variant: str, batch: int, out_dir: str,
                tag: str):
    header, records = export.read_qam_raw(qam_path)
    step, cfg = build_step(header, records, variant)
    x = jax.ShapeDtypeStruct((batch, cfg.input_dim), jnp.float32)
    state_specs = []
    state_names = []
    for l in range(cfg.num_layers):
        state_specs.append(
            jax.ShapeDtypeStruct((batch, cfg.cell_dim), jnp.float32)
        )
        state_specs.append(
            jax.ShapeDtypeStruct((batch, cfg.rec_dim), jnp.float32)
        )
        state_names += [f"l{l}.c", f"l{l}.h"]
    lowered = jax.jit(step).lower(x, *state_specs)
    text = to_hlo_text(lowered)
    base = f"{out_dir}/{tag}.{variant}.b{batch}"
    with open(base + ".hlo.txt", "w") as fh:
        fh.write(text)
    manifest = {
        "model": tag,
        "variant": variant,
        "batch": batch,
        "input_dim": cfg.input_dim,
        "num_labels": cfg.num_labels,
        "num_layers": cfg.num_layers,
        "cell_dim": cfg.cell_dim,
        "rec_dim": cfg.rec_dim,
        "inputs": ["x"] + state_names,
        "outputs": ["log_probs"] + state_names,
        "output_is_tuple": True,
    }
    with open(base + ".json", "w") as fh:
        json.dump(manifest, fh, indent=1)
    return len(text)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batches", default="1,8")
    args = ap.parse_args()
    art = args.out
    out_dir = f"{art}/hlo"
    os.makedirs(out_dir, exist_ok=True)
    batches = [int(b) for b in args.batches.split(",")]

    # train.py writes models under cfg.name; the quickstart config is p24.
    name = model.QUICKSTART_CONFIG.name
    jobs = [
        (f"{art}/models/{name}.float.qam", FLOAT, name),
        (f"{art}/models/{name}.qat.qam", QUANT, name),
        (f"{art}/models/{name}.qat.qam", QUANT_PALLAS, name),
    ]
    for qam, variant, tag in jobs:
        if not os.path.exists(qam):
            print(f"skip {qam} (not trained)")
            continue
        for b in batches:
            n = lower_model(qam, variant, b, out_dir, tag)
            print(f"lowered {tag}.{variant}.b{b}: {n} chars")


if __name__ == "__main__":
    main()
