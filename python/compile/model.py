"""L2: the LSTM acoustic model (Sak et al. 2014 LSTMP variant) in JAX.

Architecture (paper §4): a stack of ``num_layers`` LSTM layers of
``cell_dim`` cells, optionally each followed by a linear recurrent
projection layer of ``proj_dim`` units, topped by a softmax output layer
over ``N_LABELS`` (40 phones + CTC blank).

Execution modes (paper Table 1 columns):
    ``float``      — f32 everywhere ('match' training / eval path).
    ``quant``      — every matmul runs through the §3.1 quantized path
                     (inputs quantized on the fly per-tensor, weights
                     per-matrix) EXCEPT the final softmax layer.
    ``quant_all``  — as ``quant`` but the output layer is quantized too.

The quantized forward here uses :func:`quantlib.fake_quant_ste` /
``fake_quant`` — mathematically identical to the integer pipeline of eq. (1)
(``V''_a·V''_b/(Qa·Qb) == recover(a)·recover(b)`` summed), which the kernel
tests assert.  Training (QAT, §3.2) therefore gets inference-exact numerics
in the forward pass while gradients flow straight-through to the
full-precision master weights.

``step`` is the single-timestep function that gets AOT-lowered (aot.py) and
executed by the rust runtime; ``forward`` scans it over time for training.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import quantlib, spec

# Execution modes
FLOAT = "float"
QUANT = "quant"
QUANT_ALL = "quant_all"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Acoustic model architecture (one row of Table 1)."""

    num_layers: int
    cell_dim: int
    proj_dim: Optional[int] = None
    input_dim: int = spec.FEAT_DIM
    num_labels: int = spec.N_LABELS

    @property
    def name(self) -> str:
        if self.proj_dim is not None:
            return f"p{self.proj_dim}"
        return f"{self.num_layers}x{self.cell_dim}"

    @property
    def rec_dim(self) -> int:
        """Dimension fed recurrently and to the next layer (P or N)."""
        return self.proj_dim if self.proj_dim is not None else self.cell_dim

    def layer_in_dim(self, layer: int) -> int:
        return self.input_dim if layer == 0 else self.rec_dim

    def param_count(self) -> int:
        total = 0
        for l in range(self.num_layers):
            total += self.layer_in_dim(l) * 4 * self.cell_dim      # W_x
            total += self.rec_dim * 4 * self.cell_dim              # W_h
            total += 4 * self.cell_dim                             # b
            if self.proj_dim is not None:
                total += self.cell_dim * self.proj_dim             # W_p
        total += self.rec_dim * self.num_labels + self.num_labels  # softmax
        return total


# The Table-1 architecture grid, scaled ~×1/10 in width (DESIGN.md §2).
# Paper: 4-5 layers × {300,400,500} cells; P ∈ {100..400} on a 5×500 stack.
TABLE1_CONFIGS = [
    ModelConfig(4, 30), ModelConfig(5, 30),
    ModelConfig(4, 40), ModelConfig(5, 40),
    ModelConfig(4, 50), ModelConfig(5, 50),
    ModelConfig(5, 50, proj_dim=10), ModelConfig(5, 50, proj_dim=20),
    ModelConfig(5, 50, proj_dim=30), ModelConfig(5, 50, proj_dim=40),
]
QUICKSTART_CONFIG = ModelConfig(3, 48, proj_dim=24)
FIGURE2_CONFIG = ModelConfig(5, 50, proj_dim=20)   # paper's P=200 analog


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """Uniform ±1/√fan_in init; forget-gate bias +1 for training stability.

    (Fan-in scaling keeps the activation magnitude roughly unit through the
    stack — a fixed ±0.05 collapses the signal by ~10⁻⁶ over 3 LSTMP layers
    and CTC then sticks in the all-blank plateau.)"""

    def uni(key, shape, scale=None):
        if scale is None:
            scale = (3.0 / float(shape[0])) ** 0.5  # Glorot-style gain 1
        return jax.random.uniform(key, shape, jnp.float32, -scale, scale)

    params = {}
    for l in range(cfg.num_layers):
        key, k1, k2, k3 = jax.random.split(key, 4)
        params[f"l{l}.wx"] = uni(k1, (cfg.layer_in_dim(l), 4 * cfg.cell_dim))
        params[f"l{l}.wh"] = uni(k2, (cfg.rec_dim, 4 * cfg.cell_dim))
        b = jnp.zeros((4 * cfg.cell_dim,), jnp.float32)
        # forget gate block is [N:2N] (layout [i|f|g|o])
        b = b.at[cfg.cell_dim : 2 * cfg.cell_dim].set(1.0)
        params[f"l{l}.b"] = b
        if cfg.proj_dim is not None:
            params[f"l{l}.wp"] = uni(k3, (cfg.cell_dim, cfg.proj_dim))
    key, k1 = jax.random.split(key)
    params["out.w"] = uni(k1, (cfg.rec_dim, cfg.num_labels))
    params["out.b"] = jnp.zeros((cfg.num_labels,), jnp.float32)
    return params


def init_state(cfg: ModelConfig, batch: int) -> dict:
    """Zero recurrent state: per layer (c [B,N], h [B,rec])."""
    st = {}
    for l in range(cfg.num_layers):
        st[f"l{l}.c"] = jnp.zeros((batch, cfg.cell_dim), jnp.float32)
        st[f"l{l}.h"] = jnp.zeros((batch, cfg.rec_dim), jnp.float32)
    return st


def _mode_scale(mode: str) -> float:
    """Quantization scale for a mode string.

    ``quant``/``quant_all`` → 255 (8 bits); ``quant<b>``/``quant_all<b>``
    (e.g. ``quant4``) → 2^b − 1, the E5/QAT-bits extension."""
    digits = "".join(c for c in mode if c.isdigit())
    bits = int(digits) if digits else 8
    return float((1 << bits) - 1)


def _mm(x, w, mode: str):
    """Matmul under the requested numerics (float vs §3.1 quantized)."""
    if mode == FLOAT:
        return x @ w
    # Quantized path — fake-quant == integer pipeline (see module docstring).
    scale = _mode_scale(mode)
    xq = quantlib.fake_quant_ste(x)          # activations stay 8-bit
    wq = quantlib.fake_quant_ste(w, scale=scale)
    return xq @ wq


def step(params: dict, cfg: ModelConfig, x_t: jnp.ndarray, state: dict,
         mode: str = FLOAT) -> tuple:
    """One timestep: features [B, D] + state → (logits [B, L], new state).

    ``mode`` selects Table-1 numerics.  In ``quant`` mode the final softmax
    matmul stays float; ``quant_all`` quantizes it as well.
    """
    inner = FLOAT if mode == FLOAT else ("quant" + "".join(c for c in mode if c.isdigit()))
    h_in = x_t
    new_state = {}
    for l in range(cfg.num_layers):
        gates = (
            _mm(h_in, params[f"l{l}.wx"], inner)
            + _mm(state[f"l{l}.h"], params[f"l{l}.wh"], inner)
            + params[f"l{l}.b"]
        )
        n = cfg.cell_dim
        i_g = jax.nn.sigmoid(gates[:, 0 * n:1 * n])
        f_g = jax.nn.sigmoid(gates[:, 1 * n:2 * n])
        g_g = jnp.tanh(gates[:, 2 * n:3 * n])
        o_g = jax.nn.sigmoid(gates[:, 3 * n:4 * n])
        c_new = f_g * state[f"l{l}.c"] + i_g * g_g
        h_new = o_g * jnp.tanh(c_new)
        if cfg.proj_dim is not None:
            h_new = _mm(h_new, params[f"l{l}.wp"], inner)
        new_state[f"l{l}.c"] = c_new
        new_state[f"l{l}.h"] = h_new
        h_in = h_new
    out_mode = inner if mode.startswith("quant_all") else FLOAT
    logits = _mm(h_in, params["out.w"], out_mode) + params["out.b"]
    return logits, new_state


def forward(params: dict, cfg: ModelConfig, feats: jnp.ndarray,
            mode: str = FLOAT) -> jnp.ndarray:
    """Full-sequence forward: feats [B, T, D] → logits [B, T, L] (scan)."""
    batch = feats.shape[0]
    state0 = init_state(cfg, batch)

    def body(state, x_t):
        logits, state = step(params, cfg, x_t, state, mode=mode)
        return state, logits

    _, logits = jax.lax.scan(body, state0, jnp.swapaxes(feats, 0, 1))
    return jnp.swapaxes(logits, 0, 1)


def log_posteriors(params, cfg, feats, mode=FLOAT):
    return jax.nn.log_softmax(forward(params, cfg, feats, mode), axis=-1)


# ---------------------------------------------------------------------------
# SVD-based projection initialization (paper §5.1, 'SVD initialization')
# ---------------------------------------------------------------------------


def svd_init_from_uncompressed(
    params_unc: dict, cfg_unc: ModelConfig, cfg_proj: ModelConfig,
) -> dict:
    """Initialize a projection model from an uncompressed one [23].

    Each recurrent matrix W_h [N, 4N] of the uncompressed model is factored
    by truncated SVD: W_h ≈ (U_k Σ_k)(V_kᵀ) with rank k = P.  The projection
    matrix gets W_p = V_k [N→P] and the new recurrent matrix
    W_h' = (U_k Σ_k) [P→4N] — wait: dimensional bookkeeping below.

    Concretely with W_h: [rec=N, 4N] and target [P, 4N] plus W_p: [N, P]:
        W_h ≈ W_p @ W_h'   where  W_p = U_k Σ_k  [N, P],  W_h' = V_kᵀ [P, 4N].
    Inter-layer input matrices W_x (which consume the projected h of the
    previous layer) are truncated the same way through the previous layer's
    W_p basis.
    """
    assert cfg_proj.proj_dim is not None
    assert cfg_unc.cell_dim == cfg_proj.cell_dim
    assert cfg_unc.num_layers == cfg_proj.num_layers
    p = cfg_proj.proj_dim
    out = {}
    prev_basis = None  # [N, P] mapping of previous layer's h to proj space
    for l in range(cfg_proj.num_layers):
        wh = params_unc[f"l{l}.wh"]            # [N, 4N]
        u, s, vt = jnp.linalg.svd(wh, full_matrices=False)
        wp = u[:, :p] * s[:p][None, :]         # [N, P]
        wh_new = vt[:p, :]                     # [P, 4N]
        wx = params_unc[f"l{l}.wx"]            # [in, 4N]
        if l > 0:
            # The previous layer now emits r = h @ W_p instead of h.  The
            # least-squares W_x' with r @ W_x' ≈ h @ W_x is pinv(W_p) @ W_x:
            # [P, N] @ [N, 4N] → [P, 4N].
            wx = jnp.linalg.pinv(prev_basis) @ wx
        out[f"l{l}.wx"] = wx
        out[f"l{l}.wh"] = wh_new
        out[f"l{l}.b"] = params_unc[f"l{l}.b"]
        out[f"l{l}.wp"] = wp
        prev_basis = wp
    wo = params_unc["out.w"]                   # [N, L]
    out["out.w"] = jnp.linalg.pinv(prev_basis) @ wo
    out["out.b"] = params_unc["out.b"]
    return out


# ---------------------------------------------------------------------------
# Parameter-space helpers shared by train/export
# ---------------------------------------------------------------------------


def quantized_view(params: dict, quantize_output: bool) -> dict:
    """Post-training quantization ('mismatch' condition): every weight
    matrix fake-quantized per-matrix; biases stay float (paper Fig. 1 adds
    biases after recovery)."""
    out = {}
    for k, v in params.items():
        is_matrix = v.ndim == 2
        is_out = k.startswith("out.")
        if is_matrix and (quantize_output or not is_out):
            out[k] = quantlib.fake_quant(v)
        else:
            out[k] = v
    return out
