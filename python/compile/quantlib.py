"""The paper's §3 quantization scheme, in JAX.

Uniform linear quantizer over a range ``R = vmax - vmin`` onto the scale
``S = 255`` (8 bits):

    Q   = S / R                                  (quantization factor)
    V'  = round(Q*V) - round(Q*vmin)             (eq. 2 — quantize)
    V   = (V' + round(Q*vmin)) / Q               (eq. 3 — recover)

The ``round(Q*vmin)`` term is the *zero point* ``zp``.  Keeping the SAME
rounded zero point in eq. 2 and eq. 3 is what cancels the bias error the
paper discusses in §3 ("Integer multiplication: effects on quantization and
recovery"): the offset-shifted integer ``V'' = V' + zp = round(Q*V)`` is then
an unbiased fixed-point representation of ``Q*V``.

Products of two independently quantized tensors recover with the inverse
product of their factors (eq. 1):

    Vc = (Va'' * Vb'') / (Qa * Qb)

A *naive* variant (``quantize_naive``) floors instead of rounding and applies
the float (unrounded) offset at recovery; it exists purely as the bias-error
ablation baseline (experiment E2 in DESIGN.md).

All functions are shape-polymorphic and jit-safe.  ``QParams`` holds scalars
(or per-row vectors, for the granularity ablation E3).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import spec

S = float(spec.QUANT_SCALE)  # 255.0
# Minimum quantization range: degenerate (all-equal) tensors would give
# Q ~ 1e14 whose f32 products cancel catastrophically in eq. (2).  1e-6
# keeps every intermediate exactly representable enough (error ≤ ~1e-6/Q).
MIN_RANGE = 1e-6


class QParams(NamedTuple):
    """Quantization parameters: ``q`` factor and integer zero point ``zp``.

    ``vmin`` is retained for inspection/export; ``zp == round(q*vmin)``.
    Fields are scalars for per-tensor granularity or ``[rows, 1]`` arrays for
    per-row granularity.
    """

    q: jnp.ndarray
    zp: jnp.ndarray   # float dtype but integer-valued
    vmin: jnp.ndarray


def compute_qparams(v: jnp.ndarray, axis=None, scale: float = S) -> QParams:
    """Derive (Q, zp) from the min/max of ``v``.

    ``axis=None`` → per-tensor; ``axis=1`` on a 2-D matrix → per-row
    (keepdims), the sub-matrix granularity knob of §3.1.  ``scale`` is
    ``2^bits − 1`` (255 default; smaller for the E5 bit-width ablation).
    Degenerate ranges (all-equal tensors) quantize to mid-scale losslessly.
    """
    vmin = jnp.min(v, axis=axis, keepdims=axis is not None)
    vmax = jnp.max(v, axis=axis, keepdims=axis is not None)
    rng = jnp.maximum(vmax - vmin, MIN_RANGE)
    q = scale / rng
    zp = jnp.round(q * vmin)
    return QParams(q=q, zp=zp, vmin=vmin)


def quantize(v: jnp.ndarray, p: QParams, scale: float = S) -> jnp.ndarray:
    """Eq. 2: ``V' = round(Q*V) - round(Q*vmin)``, clipped to [0, scale].

    Returns float-dtype integers (uint8-valued); stays in float for jit
    friendliness — the Pallas kernels cast to int32 for the MXU path.
    """
    return jnp.clip(jnp.round(p.q * v) - p.zp, 0.0, scale)


def recover(vq: jnp.ndarray, p: QParams) -> jnp.ndarray:
    """Eq. 3: ``V = (V' + zp) / Q`` — consistent with :func:`quantize`."""
    return (vq + p.zp) / p.q


def fake_quant(v: jnp.ndarray, axis=None, scale: float = S) -> jnp.ndarray:
    """Quantize-then-recover (the QAT forward transform), no gradient magic."""
    p = compute_qparams(v, axis=axis, scale=scale)
    return recover(quantize(v, p, scale=scale), p)


def fake_quant_ste(v: jnp.ndarray, axis=None, scale: float = S) -> jnp.ndarray:
    """QAT straight-through fake-quant (paper §3.2 / Algorithm 1).

    Forward: quantized-then-recovered value (inference numerics).
    Backward: identity — the gradient is computed "in full precision ...
    based on the error from the quantized forward pass", and applied to the
    full-precision master weights.  The paper explicitly does NOT add a
    quantization term to the backward pass.
    """
    return v + jax.lax.stop_gradient(fake_quant(v, axis=axis, scale=scale) - v)


def quantized_matmul(x: jnp.ndarray, w: jnp.ndarray, wp: QParams) -> jnp.ndarray:
    """Figure 1 inference path for ``y = x @ w`` (pure-jnp reference).

    ``x`` is float input quantized on the fly per-tensor; ``w`` arrives
    pre-quantized with params ``wp``.  Mathematically this is eq. (1),
    ``Σ V''x·V''w / (Qx·Qw)``, but computed with the zero points folded out
    (the standard gemmlowp expansion, identical to rust quant/gemm.rs):

        Σ (x'+zpx)(w'+zpw) = Σ x'w' + zpx·Σw' + zpw·Σx' + K·zpx·zpw

    so the i32 accumulator only ever sees u8·u8 products (≤ 255²·K — no
    overflow even for pathologically off-center ranges where V'' itself
    would exceed i32 when squared).  The correction terms are applied in
    f32 — they are exact there relative to the final 1/(Qx·Qw) scaling.

    The Pallas kernel (kernels/qmatmul.py) implements the same algebra
    tile-by-tile; this function is its oracle.
    """
    wq = quantize(w, wp)
    return quantized_matmul_q(x, wq, wp)


def quantized_matmul_q(x: jnp.ndarray, wq: jnp.ndarray, wp: QParams) -> jnp.ndarray:
    """As :func:`quantized_matmul` but with the weights already in eq. 2
    form (u8-valued ``V'``) — the shape used at inference when weights are
    stored quantized (.qam files, AOT graphs)."""
    xp = compute_qparams(x)
    xq = quantize(x, xp)                     # u8-valued float
    k = x.shape[-1]
    acc = jax.lax.dot_general(
        xq.astype(jnp.int32), wq.astype(jnp.int32),
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    x_sums = jnp.sum(xq, axis=-1, keepdims=True)          # Σx' per row
    w_sums = jnp.sum(wq, axis=0, keepdims=True)           # Σw' per col
    full = (
        acc
        + xp.zp * w_sums
        + wp.zp * x_sums
        + jnp.asarray(k, jnp.float32) * xp.zp * wp.zp
    )
    return full / (xp.q * wp.q)


def quantize_naive(v: jnp.ndarray, p: QParams) -> jnp.ndarray:
    """Bias-error ablation: truncating quantizer (floor of shifted value).

    Mirrors rust `NaiveQuantParams`: every value lands on the grid point
    below it, so recovery keeps a systematic −½·step bias."""
    return jnp.clip(jnp.floor(p.q * (v - p.vmin)), 0.0, S)


def recover_naive(vq: jnp.ndarray, p: QParams) -> jnp.ndarray:
    """Bias-error ablation: recovery with the *unrounded* float offset.

    The mismatch between ``floor``/float-offset here and the integer
    arithmetic of eq. 1 is exactly the inconsistency §3 warns about; the E2
    ablation measures the systematic bias it introduces.
    """
    return vq / p.q + p.vmin


def quant_error_stats(v: jnp.ndarray, consistent: bool = True):
    """Mean (bias) and RMS of the quantization error, for E2.

    With the consistent scheme the error is pure precision loss: zero-mean,
    RMS ≈ 1/(Q*sqrt(12)).  The naive scheme shows a ~half-step bias.
    """
    p = compute_qparams(v)
    if consistent:
        r = recover(quantize(v, p), p)
    else:
        r = recover_naive(quantize_naive(v, p), p)
    err = r - v
    return jnp.mean(err), jnp.sqrt(jnp.mean(err * err))
