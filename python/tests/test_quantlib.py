"""quantlib: eqs. (1)-(3) invariants, QAT transform, bias ablation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantlib
from compile.quantlib import (QParams, compute_qparams, fake_quant,
                              fake_quant_ste, quantize, quantize_naive,
                              quantized_matmul, quantized_matmul_q, recover,
                              recover_naive)


def rand(shape, lo, hi, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape), jnp.float32)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 200),
    lo=st.floats(-10.0, 0.0),
    width=st.floats(0.05, 20.0),
    seed=st.integers(0, 1000),
)
def test_roundtrip_error_bounded_by_half_step(n, lo, width, seed):
    v = rand((n,), lo, lo + width, seed)
    p = compute_qparams(v)
    r = recover(quantize(v, p), p)
    half = 0.5 / p.q
    # 1% headroom + small absolute: f32 arithmetic adds epsilon-level error
    # (|q·v| can be ~1e4 with only 24-bit mantissas) on top of the exact
    # half-step quantization bound.
    err = float(jnp.max(jnp.abs(r - v)))
    assert err <= float(half) * 1.01 + 1e-6 * (1.0 + abs(lo))


def test_quantized_values_span_scale():
    v = jnp.linspace(0.0, 1.0, 101)
    p = compute_qparams(v)
    q = quantize(v, p)
    assert float(q[0]) == 0.0
    assert float(q[-1]) == 255.0
    assert float(jnp.min(q)) >= 0.0 and float(jnp.max(q)) <= 255.0


def test_consistent_bias_much_smaller_than_naive():
    v = rand((65536,), -1.0, 1.0, 3)
    p = compute_qparams(v)
    err_c = recover(quantize(v, p), p) - v
    err_n = recover_naive(quantize_naive(v, p), p) - v
    assert abs(float(jnp.mean(err_c))) < 2e-4
    assert abs(float(jnp.mean(err_n))) > 5 * abs(float(jnp.mean(err_c)))
    # the naive bias is ~ -half step
    assert float(jnp.mean(err_n)) < 0


def test_shifted_integer_equals_round_qv():
    v = rand((100,), -2.0, 3.0, 4)
    p = compute_qparams(v)
    shifted = quantize(v, p) + p.zp
    assert np.allclose(np.asarray(shifted), np.round(np.asarray(p.q * v)))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 8),
    k=st.integers(1, 64),
    n=st.integers(1, 32),
    seed=st.integers(0, 99),
)
def test_quantized_matmul_close_to_float(m, k, n, seed):
    x = rand((m, k), -2.0, 2.0, seed)
    w = rand((k, n), -0.5, 0.5, seed + 1)
    wp = compute_qparams(w)
    got = quantized_matmul(x, w, wp)
    want = x @ w
    scale = float(jnp.max(jnp.abs(want))) + 1.0
    assert float(jnp.max(jnp.abs(got - want))) < 0.05 * scale


def test_quantized_matmul_q_matches_quantized_matmul():
    x = rand((4, 32), -1.0, 1.0, 7)
    w = rand((32, 16), -0.7, 0.7, 8)
    wp = compute_qparams(w)
    wq = quantize(w, wp)
    a = quantized_matmul(x, w, wp)
    b = quantized_matmul_q(x, wq, wp)
    assert np.allclose(np.asarray(a), np.asarray(b))


def test_fake_quant_equals_integer_pipeline():
    # fake-quant matmul == eq. (1) integer matmul (the QAT faithfulness
    # claim in model.py's docstring).
    x = rand((3, 24), -1.5, 1.5, 9)
    w = rand((24, 10), -0.4, 0.4, 10)
    wp = compute_qparams(w)
    xp = compute_qparams(x)
    xf = recover(quantize(x, xp), xp)
    wf = recover(quantize(w, wp), wp)
    want = xf @ wf
    got = quantized_matmul(x, w, wp)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4


def test_fake_quant_ste_gradient_is_identity():
    v = rand((16, 16), -1.0, 1.0, 11)

    def f(w):
        return jnp.sum(fake_quant_ste(w) ** 2)

    g = jax.grad(f)(v)
    # STE: d/dw sum(fq(w)^2) ≈ 2*fq(w) (gradient flows as if identity)
    want = 2 * fake_quant(v)
    assert float(jnp.max(jnp.abs(g - want))) < 1e-5


def test_degenerate_range_safe():
    v = jnp.full((7,), 3.0)
    p = compute_qparams(v)
    r = recover(quantize(v, p), p)
    assert float(jnp.max(jnp.abs(r - 3.0))) < 1e-3


def test_per_row_granularity_reduces_error():
    rng = np.random.default_rng(5)
    w = rng.normal(0, 0.1, size=(32, 64)).astype(np.float32)
    w[0] *= 10
    w = jnp.asarray(w)
    err = lambda axis: float(jnp.sqrt(jnp.mean((fake_quant(w, axis=axis) - w) ** 2)))
    assert err(1) < err(None)
