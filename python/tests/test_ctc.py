"""CTC loss vs brute-force enumeration + batching/masking invariants."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import ctc


def brute_force_nll(log_probs, labels, blank=0):
    """Sum over all alignments that collapse to `labels`."""
    t, l = log_probs.shape
    total = -np.inf
    for path in itertools.product(range(l), repeat=t):
        seq, prev = [], blank
        for s in path:
            if s != blank and s != prev:
                seq.append(s)
            prev = s
        if seq == list(labels):
            lp = sum(log_probs[i, path[i]] for i in range(t))
            total = np.logaddexp(total, lp)
    return -total


def rand_logprobs(t, l, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(1, t, l)), jnp.float32)
    return jax.nn.log_softmax(logits, -1)


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 6),
    l=st.integers(2, 4),
    u=st.integers(0, 3),
    seed=st.integers(0, 500),
)
def test_ctc_matches_brute_force(t, l, u, seed):
    rng = np.random.default_rng(seed + 10_000)
    labels = rng.integers(1, l, size=u)
    # CTC needs t >= required frames (repeated labels need a blank gap)
    required = u + sum(labels[i] == labels[i - 1] for i in range(1, u))
    if t < required:
        return
    lp = rand_logprobs(t, l, seed)
    pad = max(u, 1)
    lab = np.zeros((1, pad), np.int32)
    lab[0, :u] = labels
    got = float(
        ctc.ctc_loss(lp, jnp.asarray(lab), jnp.asarray([t]), jnp.asarray([u]))[0]
    )
    want = brute_force_nll(np.asarray(lp[0]), labels)
    assert got == pytest.approx(want, rel=1e-4, abs=1e-4)


def test_batch_equals_individual():
    lp1 = rand_logprobs(8, 5, 1)
    lp2 = rand_logprobs(8, 5, 2)
    l1 = np.array([[1, 2, 0]], np.int32)
    l2 = np.array([[3, 3, 4]], np.int32)
    a = float(ctc.ctc_loss(lp1, jnp.asarray(l1), jnp.asarray([8]), jnp.asarray([2]))[0])
    b = float(ctc.ctc_loss(lp2, jnp.asarray(l2), jnp.asarray([8]), jnp.asarray([3]))[0])
    batch_lp = jnp.concatenate([lp1, lp2], axis=0)
    batch_lab = jnp.asarray(np.concatenate([l1, l2], axis=0))
    both = ctc.ctc_loss(batch_lp, batch_lab, jnp.asarray([8, 8]), jnp.asarray([2, 3]))
    assert float(both[0]) == pytest.approx(a, rel=1e-5)
    assert float(both[1]) == pytest.approx(b, rel=1e-5)


def test_padding_frames_are_ignored():
    lp = rand_logprobs(6, 4, 3)
    lab = jnp.asarray([[1, 2]], jnp.int32)
    short = float(ctc.ctc_loss(lp, lab, jnp.asarray([4]), jnp.asarray([2]))[0])
    # pad with 4 extra frames of random data; input_length stays 4
    extra = rand_logprobs(4, 4, 4)
    padded = jnp.concatenate([lp, extra], axis=1)
    got = float(ctc.ctc_loss(padded, lab, jnp.asarray([4]), jnp.asarray([2]))[0])
    assert got == pytest.approx(short, rel=1e-5)


def test_impossible_label_longer_than_input():
    lp = rand_logprobs(2, 4, 5)
    lab = jnp.asarray([[1, 2, 3]], jnp.int32)
    nll = float(ctc.ctc_loss(lp, lab, jnp.asarray([2]), jnp.asarray([3]))[0])
    assert nll > 1e9  # -NEG_INF-ish: zero probability


def test_gradient_flows():
    lp = rand_logprobs(6, 4, 6)
    lab = jnp.asarray([[1, 2]], jnp.int32)

    def f(x):
        return ctc.ctc_loss(
            jax.nn.log_softmax(x, -1), lab, jnp.asarray([6]), jnp.asarray([2])
        )[0]

    g = jax.grad(f)(lp)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.max(jnp.abs(g))) > 0


def test_greedy_decode_and_ler():
    # peaked posteriors → greedy recovers the sequence
    t, l = 7, 4
    ids = [1, 1, 0, 2, 0, 3, 3]
    logits = np.full((1, t, l), -5.0, np.float32)
    for i, s in enumerate(ids):
        logits[0, i, s] = 5.0
    lp = jax.nn.log_softmax(jnp.asarray(logits), -1)
    hyps = ctc.greedy_decode(lp, np.asarray([t]))
    assert hyps[0] == [1, 2, 3]
    assert ctc.label_error_rate(hyps, [[1, 2, 3]]) == 0.0
    assert ctc.label_error_rate(hyps, [[1, 3]]) == pytest.approx(0.5)


def test_edit_distance():
    assert ctc.edit_distance([], []) == 0
    assert ctc.edit_distance([1, 2], [1, 2]) == 0
    assert ctc.edit_distance([1, 2, 3], [1, 3]) == 1
    assert ctc.edit_distance([1], [2, 3, 4]) == 3
