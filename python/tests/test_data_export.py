"""Data pipeline + export formats: determinism, frontend maths, round-trips."""

import numpy as np
import pytest

from compile import data, export, model, spec


@pytest.fixture(scope="module")
def world():
    return spec.World()


def test_world_derivation_deterministic(world):
    w2 = spec.World()
    assert world.lexicon == w2.lexicon
    assert [p.formants for p in world.phones] == [p.formants for p in w2.phones]
    assert world.bigram == w2.bigram


def test_lexicon_shapes(world):
    assert len(world.lexicon) == spec.N_WORDS
    assert all(2 <= len(s) <= 6 for s in world.lexicon)
    assert len({tuple(s) for s in world.lexicon}) == spec.N_WORDS
    assert all(1 <= p <= spec.N_PHONES for s in world.lexicon for p in s)


def test_mel_filterbank_properties():
    fb = data.mel_filterbank()
    assert fb.shape == (spec.N_MEL, spec.FFT_SIZE // 2 + 1)
    assert (fb >= 0).all()
    assert (fb.sum(axis=1) > 0).all()
    # DC and Nyquist excluded (fmin=125, fmax=3800)
    assert (fb[:, 0] == 0).all()
    assert (fb[:, -1] == 0).all()


def test_features_shape_and_scale(world):
    rng = spec.SplitMix64(7)
    nprng = np.random.default_rng(7)
    wave, phones, align = data.synth_utterance([3, 5], world, rng, nprng)
    f = data.features(wave)
    assert f.shape[1] == spec.FEAT_DIM
    t_raw = 1 + (len(wave) - spec.FRAME_LEN) // spec.FRAME_HOP
    assert f.shape[0] == (t_raw - spec.STACK) // spec.DECIMATE + 1
    # FEAT_SCALE applied → roughly unit variance
    assert 0.1 < float(f.std()) < 3.0


def test_stacking_matches_manual():
    t_raw, m = 10, spec.N_MEL
    frames = np.arange(t_raw * m, dtype=np.float32).reshape(t_raw, m)
    out = data.stack_frames(frames)
    # frame 1 covers raw frames 2..5
    want = np.concatenate([frames[2], frames[3], frames[4], frames[5]])
    np.testing.assert_allclose(out[1], want)


def test_gen_utt_deterministic(world):
    a = data.gen_utt(5, 101, world, "clean")
    b = data.gen_utt(5, 101, world, "clean")
    np.testing.assert_array_equal(a.feats, b.feats)
    np.testing.assert_array_equal(a.phones, b.phones)


def test_clean_noisy_share_content(world):
    c = data.gen_utt(9, 303, world, "clean")
    n = data.gen_utt(9, 303, world, "noisy")
    np.testing.assert_array_equal(c.words, n.words)
    assert not np.allclose(c.feats, n.feats)


def test_feats_file_roundtrip(tmp_path, world):
    utts = [data.gen_utt(i, 11, world, "clean") for i in range(5)]
    p = tmp_path / "t.feats"
    data.write_feats(str(p), utts)
    back = data.read_feats(str(p))
    assert len(back) == 5
    for a, b in zip(utts, back):
        np.testing.assert_allclose(a.feats, b.feats)
        np.testing.assert_array_equal(a.phones, b.phones)
        np.testing.assert_array_equal(a.align, b.align)


def test_qam_roundtrip_float_and_quant(tmp_path):
    import jax

    cfg = model.ModelConfig(2, 8, proj_dim=4)
    params = model.init_params(cfg, jax.random.PRNGKey(3))
    for quantized, qo in [(False, False), (True, False), (True, True)]:
        p = tmp_path / f"m_{quantized}_{qo}.qam"
        export.write_qam(str(p), params, cfg, quantized=quantized, quantize_output=qo)
        header, back, qinfo = export.read_qam(str(p))
        assert header["quantized"] == quantized
        cfg2 = export.config_from_header(header)
        assert cfg2 == cfg
        for k, v in params.items():
            got = back[k]
            if quantized and got.ndim == 2 and (qo or not k.startswith("out.")):
                # quantized: within half a step
                q = qinfo[k][1]
                assert np.max(np.abs(got - np.asarray(v))) <= 0.5 / q * 1.01
            else:
                np.testing.assert_allclose(got, np.asarray(v), atol=1e-7)


def test_qam_quantized_file_smaller(tmp_path):
    import jax
    import os

    cfg = model.ModelConfig(3, 32, proj_dim=16)
    params = model.init_params(cfg, jax.random.PRNGKey(4))
    pf = tmp_path / "f.qam"
    pq = tmp_path / "q.qam"
    export.write_qam(str(pf), params, cfg, quantized=False)
    export.write_qam(str(pq), params, cfg, quantized=True, quantize_output=True)
    assert os.path.getsize(pq) * 3 < os.path.getsize(pf)


def test_read_qam_raw_preserves_u8(tmp_path):
    import jax

    cfg = model.ModelConfig(1, 8)
    params = model.init_params(cfg, jax.random.PRNGKey(5))
    p = tmp_path / "r.qam"
    export.write_qam(str(p), params, cfg, quantized=True)
    _, records = export.read_qam_raw(str(p))
    dtype, arr, vmin, q = records["l0.wx"]
    assert dtype == export.U8Q
    assert arr.dtype == np.uint8
    assert vmin is not None and q is not None
    assert arr.min() >= 0 and arr.max() <= 255
