"""sMBR approximation + training machinery (schedules, batching, SGD)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, smbr, train


def test_collapse_paths():
    paths = jnp.asarray(
        [[[0, 1, 1, 0, 2, 2, 3]]], jnp.int32
    )  # [K=1, B=1, T=7]
    labels, lengths = smbr.collapse_paths(paths, jnp.asarray([7]))
    assert int(lengths[0, 0]) == 3
    assert list(np.asarray(labels[0, 0][:3])) == [1, 2, 3]


def test_collapse_respects_input_length():
    paths = jnp.asarray([[[1, 0, 2, 3, 3]]], jnp.int32)
    labels, lengths = smbr.collapse_paths(paths, jnp.asarray([3]))
    assert int(lengths[0, 0]) == 2
    assert list(np.asarray(labels[0, 0][:2])) == [1, 2]


@pytest.mark.parametrize(
    "a,la,b,lb,want",
    [
        ([1, 2, 3], 3, [1, 2, 3], 3, 0),
        ([1, 2, 3], 3, [1, 3], 2, 1),
        ([], 0, [1, 2], 2, 2),
        ([5, 5], 2, [], 0, 2),
        ([1, 9, 3, 0], 3, [1, 2, 3, 0], 3, 1),
    ],
)
def test_edit_distance_padded(a, la, b, lb, want):
    pad = 6
    av = jnp.asarray(a + [0] * (pad - len(a)), jnp.int32)
    bv = jnp.asarray(b + [0] * (pad - len(b)), jnp.int32)
    got = float(
        smbr.edit_distance_padded(av, jnp.asarray(float(la)), bv, jnp.asarray(float(lb)))
    )
    assert got == want


def test_smbr_risk_zero_when_model_is_perfect():
    # construct posteriors that deterministically emit the reference
    t, l = 8, 5
    ref_path = [1, 1, 0, 2, 0, 3, 0, 0]
    logits = np.full((1, t, l), -30.0, np.float32)
    for i, s in enumerate(ref_path):
        logits[0, i, s] = 0.0
    lp = jax.nn.log_softmax(jnp.asarray(logits), -1)
    labels = jnp.asarray([[1, 2, 3]], jnp.int32)
    risk, min_risk = smbr.smbr_risk(
        jax.random.PRNGKey(0), lp, labels, jnp.asarray([t]), jnp.asarray([3])
    )
    assert float(min_risk) == 0.0
    # baseline-subtracted expected risk ≈ 0 when all paths agree
    assert abs(float(risk)) < 1e-3


def test_smbr_gradient_finite():
    cfg = model.ModelConfig(1, 8)
    params = model.init_params(cfg, jax.random.PRNGKey(1))
    feats = jnp.asarray(np.random.default_rng(0).normal(size=(2, 6, 64)), jnp.float32)

    def loss(p):
        lp = model.log_posteriors(p, cfg, feats, "quant")
        r, _ = smbr.smbr_risk(
            jax.random.PRNGKey(2), lp, jnp.asarray([[1, 2], [3, 0]]),
            jnp.asarray([6, 6]), jnp.asarray([2, 1]),
        )
        return r

    g = jax.grad(loss)(params)
    for k, v in g.items():
        assert np.isfinite(np.asarray(v)).all(), k


# ---------------------------------------------------------------------------
# train.py machinery
# ---------------------------------------------------------------------------


def test_lr_schedules():
    assert train.eta_g(0, 0.05, 3000) == pytest.approx(0.05)
    assert train.eta_g(3000, 0.05, 3000) == pytest.approx(0.005)
    # projection multiplier ramps from c_p to 1
    assert train.eta_p_sched(0, 1e-3, 250) == pytest.approx(1e-3)
    assert train.eta_p_sched(125, 1e-3, 250) == pytest.approx(1e-3**0.5)
    assert train.eta_p_sched(250, 1e-3, 250) == pytest.approx(1.0)
    assert train.eta_p_sched(9999, 1e-3, 250) == pytest.approx(1.0)


def test_make_batches_shapes_and_content():
    class U:
        def __init__(self, t, phones):
            self.feats = np.ones((t, 64), np.float32)
            self.phones = np.asarray(phones, np.uint32)
            self.align = np.zeros(t, np.uint32)

    utts = [U(10, [1, 2]), U(33, [3]), U(7, [4, 5, 6])]
    batches = train.make_batches(utts, 2, np.random.default_rng(0), shuffle=False)
    assert len(batches) == 2
    feats, labels, t_len, u_len, align = batches[0]
    assert feats.shape[1] % 16 == 0
    assert labels.shape[1] % 8 == 0
    assert feats.shape[0] == 2
    # sorted by length: first batch has the two shortest
    assert sorted(t_len.tolist()) == [7, 10]
    assert align.shape == feats.shape[:2]


def test_sgd_update_applies_proj_multiplier():
    params = {"l0.wx": jnp.ones((2, 2)), "l0.wp": jnp.ones((2, 2))}
    vel = train.sgd_init(params)
    grads = {k: jnp.ones_like(v) for k, v in params.items()}
    lr_tree = {"l0.wx": jnp.asarray(1.0), "l0.wp": jnp.asarray(0.5)}
    new, _, _ = train.sgd_update(params, vel, grads, lr_tree, 0.0, 1e9)
    assert float(new["l0.wx"][0, 0]) == pytest.approx(0.0)
    assert float(new["l0.wp"][0, 0]) == pytest.approx(0.5)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, norm = train._clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(20.0)
    got = float(jnp.linalg.norm(clipped["a"]))
    assert got == pytest.approx(1.0, rel=1e-5)
