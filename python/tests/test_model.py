"""L2 model: shapes, modes, step-vs-scan equivalence, SVD init, QAT grads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, quantlib
from compile.model import FLOAT, QUANT, QUANT_ALL, ModelConfig


def feats(b, t, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(b, t, 64)), jnp.float32)


@pytest.fixture(scope="module")
def small():
    cfg = ModelConfig(2, 12, proj_dim=6)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_param_count_matches_init(small):
    cfg, params = small
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == cfg.param_count()


def test_table1_grid_names_and_sizes():
    names = [c.name for c in model.TABLE1_CONFIGS]
    assert names == ["4x30", "5x30", "4x40", "5x40", "4x50", "5x50",
                     "p10", "p20", "p30", "p40"]
    counts = [c.param_count() for c in model.TABLE1_CONFIGS]
    # parameter count grows within each family (paper's x-axis)
    assert counts[0] < counts[2] < counts[4]
    assert counts[6] < counts[7] < counts[8] < counts[9]


def test_forward_shapes_all_modes(small):
    cfg, params = small
    x = feats(3, 5)
    for mode in [FLOAT, QUANT, QUANT_ALL]:
        out = model.forward(params, cfg, x, mode)
        assert out.shape == (3, 5, cfg.num_labels)


def test_step_equals_scan(small):
    cfg, params = small
    x = feats(2, 6, 1)
    want = model.forward(params, cfg, x, FLOAT)
    state = model.init_state(cfg, 2)
    outs = []
    for t in range(6):
        logits, state = model.step(params, cfg, x[:, t], state, FLOAT)
        outs.append(logits)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_quant_close_to_float(small):
    cfg, params = small
    x = feats(2, 10, 2)
    lf = model.log_posteriors(params, cfg, x, FLOAT)
    lq = model.log_posteriors(params, cfg, x, QUANT)
    # quantization perturbs but does not destroy the distribution
    assert float(jnp.max(jnp.abs(lf - lq))) < 1.0
    assert float(jnp.mean(jnp.abs(lf - lq))) < 0.1


def test_quant_modes_differ(small):
    cfg, params = small
    x = feats(1, 4, 3)
    lq = model.forward(params, cfg, x, QUANT)
    lqa = model.forward(params, cfg, x, QUANT_ALL)
    assert not np.allclose(np.asarray(lq), np.asarray(lqa))


def test_no_projection_model():
    cfg = ModelConfig(2, 10)
    params = model.init_params(cfg, jax.random.PRNGKey(1))
    assert "l0.wp" not in params
    out = model.forward(params, cfg, feats(1, 3), FLOAT)
    assert out.shape == (1, 3, cfg.num_labels)


def test_svd_init_shapes_and_fidelity():
    cfg_unc = ModelConfig(2, 16)
    cfg_p = ModelConfig(2, 16, proj_dim=14)  # nearly full rank
    pu = model.init_params(cfg_unc, jax.random.PRNGKey(2))
    ps = model.svd_init_from_uncompressed(pu, cfg_unc, cfg_p)
    assert ps["l0.wp"].shape == (16, 14)
    assert ps["l0.wh"].shape == (14, 64)
    assert ps["l1.wx"].shape == (14, 64)
    # near-full-rank factorization ≈ reconstructs the recurrent matrix
    rec = np.asarray(ps["l0.wp"] @ ps["l0.wh"])
    orig = np.asarray(pu["l0.wh"])
    rel = np.linalg.norm(rec - orig) / np.linalg.norm(orig)
    assert rel < 0.35, rel


def test_qat_gradients_reach_all_params(small):
    cfg, params = small
    x = feats(2, 5, 4)

    def loss(p):
        return jnp.sum(model.forward(p, cfg, x, QUANT) ** 2)

    g = jax.grad(loss)(params)
    for k, v in g.items():
        assert np.isfinite(np.asarray(v)).all(), k
        assert float(jnp.max(jnp.abs(v))) > 0, f"no gradient for {k}"


def test_quantized_view_quantizes_matrices_only(small):
    cfg, params = small
    qv = model.quantized_view(params, quantize_output=False)
    # biases unchanged
    np.testing.assert_array_equal(np.asarray(qv["l0.b"]), np.asarray(params["l0.b"]))
    # output layer unchanged when quantize_output=False
    np.testing.assert_array_equal(np.asarray(qv["out.w"]), np.asarray(params["out.w"]))
    # weight matrices on the u8 grid: re-fake-quant is idempotent
    w = qv["l0.wx"]
    np.testing.assert_allclose(
        np.asarray(quantlib.fake_quant(w)), np.asarray(w), atol=1e-6
    )
