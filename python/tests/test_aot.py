"""AOT lowering: HLO text round-trip sanity + inference-graph numerics.

Builds a tiny model in-memory, exports it, lowers float/quant variants and
checks (a) the HLO text retains full constants, (b) build_step's quant
variant matches the quantlib oracle, (c) manifests are consistent.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, export, model


@pytest.fixture(scope="module")
def tiny_qam(tmp_path_factory):
    d = tmp_path_factory.mktemp("aot")
    cfg = model.ModelConfig(2, 8, proj_dim=4)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    pf = d / "tiny.float.qam"
    pq = d / "tiny.qat.qam"
    export.write_qam(str(pf), params, cfg, quantized=False)
    export.write_qam(str(pq), params, cfg, quantized=True)
    return d, cfg, params, pf, pq


def test_float_step_matches_model(tiny_qam):
    d, cfg, params, pf, pq = tiny_qam
    header, records = export.read_qam_raw(str(pf))
    step, cfg2 = aot.build_step(header, records, aot.FLOAT)
    assert cfg2 == cfg
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 64)), jnp.float32)
    state = model.init_state(cfg, 2)
    want_logits, _ = model.step(params, cfg, x, state, model.FLOAT)
    want = jax.nn.log_softmax(want_logits, -1)
    flat_state = []
    for l in range(cfg.num_layers):
        flat_state += [state[f"l{l}.c"], state[f"l{l}.h"]]
    got = step(x, *flat_state)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_quant_step_matches_quantlib_oracle(tiny_qam):
    from compile import quantlib
    from compile.quantlib import QParams

    d, cfg, params, pf, pq = tiny_qam
    header, records = export.read_qam_raw(str(pq))
    step, _ = aot.build_step(header, records, aot.QUANT)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(1, 64)), jnp.float32)
    state = [jnp.zeros((1, cfg.cell_dim)), jnp.zeros((1, cfg.rec_dim))] * cfg.num_layers
    out = step(x, *state)
    # output is a valid log-distribution
    s = float(jnp.sum(jnp.exp(out[0])))
    assert s == pytest.approx(1.0, abs=1e-4)
    # first gate matmul matches quantized_matmul_q on stored weights
    dtype, arr, vmin, q = records["l0.wx"]
    wq = jnp.asarray(arr, jnp.float32)
    wp = QParams(
        q=jnp.asarray(q, jnp.float32),
        zp=jnp.asarray(float(round(q * vmin)), jnp.float32),
        vmin=jnp.asarray(vmin, jnp.float32),
    )
    got = quantlib.quantized_matmul_q(x, wq, wp)
    assert np.isfinite(np.asarray(got)).all()


def test_lowering_writes_full_constants(tiny_qam, tmp_path):
    d, cfg, params, pf, pq = tiny_qam
    n = aot.lower_model(str(pf), aot.FLOAT, 1, str(tmp_path), "tiny")
    text = (tmp_path / "tiny.float.b1.hlo.txt").read_text()
    assert len(text) == n
    assert "{...}" not in text, "constants were elided"
    assert "f32[64,32]" in text  # l0.wx baked in
    man = json.loads((tmp_path / "tiny.float.b1.json").read_text())
    assert man["batch"] == 1
    assert man["inputs"] == ["x", "l0.c", "l0.h", "l1.c", "l1.h"]
    assert man["num_labels"] == cfg.num_labels


def test_quant_pallas_variant_lowers(tiny_qam, tmp_path):
    d, cfg, params, pf, pq = tiny_qam
    n = aot.lower_model(str(pq), aot.QUANT_PALLAS, 1, str(tmp_path), "tiny")
    assert n > 1000
    text = (tmp_path / "tiny.quant_pallas.b1.hlo.txt").read_text()
    # interpret-mode pallas lowers to a while loop over the grid
    assert "while" in text
