"""L1 Pallas kernels vs pure-jnp oracles (the core correctness signal).

Hypothesis sweeps shapes, value ranges and block sizes; kernels run under
interpret=True (CPU) and must match ref.py exactly (integer pipeline) or to
f32 tolerance (elementwise tails).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quantlib
from compile.kernels import lstm_step, qmatmul, ref


def rand(shape, scale, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, size=shape), jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 16),
    k=st.integers(1, 96),
    n=st.integers(1, 48),
    act=st.sampled_from(["none", "sigmoid", "tanh", "relu"]),
    seed=st.integers(0, 999),
)
def test_qmatmul_kernel_matches_ref(m, k, n, act, seed):
    x = rand((m, k), 1.0, seed)
    w = rand((k, n), 0.5, seed + 1)
    b = rand((n,), 0.3, seed + 2)
    wp = quantlib.compute_qparams(w)
    wq = quantlib.quantize(w, wp)
    xp = quantlib.compute_qparams(x)
    got = qmatmul.qmatmul(x, wq, b, xp.q, xp.zp, wp.q, wp.zp, activation=act)
    want = ref.qmatmul_ref(x, wq, b, xp.q, xp.zp, wp.q, wp.zp, activation=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([1, 2, 8, 32]),
    bn=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([16, 64, 128]),
)
def test_qmatmul_block_shape_invariance(bm, bn, bk):
    # Result must not depend on the BlockSpec tiling.
    x = rand((8, 96), 1.0, 1)
    w = rand((96, 64), 0.5, 2)
    b = jnp.zeros((64,))
    wp = quantlib.compute_qparams(w)
    wq = quantlib.quantize(w, wp)
    xp = quantlib.compute_qparams(x)
    got = qmatmul.qmatmul(x, wq, b, xp.q, xp.zp, wp.q, wp.zp, bm=bm, bn=bn, bk=bk)
    want = ref.qmatmul_ref(x, wq, b, xp.q, xp.zp, wp.q, wp.zp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5)


def test_qmatmul_integer_exactness():
    # The kernel's integer accumulation must be bit-identical to the
    # reference (same int32 algebra), so the diff is exactly zero.
    x = rand((4, 64), 2.0, 3)
    w = rand((64, 32), 0.8, 4)
    b = rand((32,), 0.1, 5)
    wp = quantlib.compute_qparams(w)
    wq = quantlib.quantize(w, wp)
    xp = quantlib.compute_qparams(x)
    got = qmatmul.qmatmul(x, wq, b, xp.q, xp.zp, wp.q, wp.zp)
    want = ref.qmatmul_ref(x, wq, b, xp.q, xp.zp, wp.q, wp.zp)
    assert float(jnp.max(jnp.abs(got - want))) == 0.0


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 16),
    n=st.integers(1, 64),
    bm=st.sampled_from([1, 4, 32]),
    seed=st.integers(0, 99),
)
def test_lstm_elementwise_matches_ref(b, n, bm, seed):
    gates = rand((b, 4 * n), 1.5, seed)
    c = rand((b, n), 1.0, seed + 1)
    h1, c1 = lstm_step.lstm_elementwise(gates, c, bm=bm)
    h2, c2 = ref.lstm_elementwise_ref(gates, c)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-6)


def test_lstm_elementwise_state_bounds():
    # |h| ≤ 1 always (o·tanh(c')), regardless of inputs.
    gates = rand((8, 4 * 32), 10.0, 6)
    c = rand((8, 32), 5.0, 7)
    h1, c1 = lstm_step.lstm_elementwise(gates, c)
    assert float(jnp.max(jnp.abs(h1))) <= 1.0 + 1e-6


def test_vmem_estimate_monotone():
    small = qmatmul.vmem_bytes(8, 128, 128)
    big = qmatmul.vmem_bytes(32, 256, 256)
    assert big > small
    # default tile fits comfortably in 16 MB VMEM
    assert qmatmul.vmem_bytes(32, 128, 128) < 16 * 1024 * 1024
