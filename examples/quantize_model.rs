//! Post-training quantization tool (the paper's 'mismatch' path as a
//! utility): load a float `.qam`, quantize every weight matrix with the
//! §3 scheme, report size/error statistics, save the quantized model, and
//! compare WER before/after on the clean eval set.
//!
//! ```bash
//! cargo run --release --example quantize_model -- \
//!     artifacts/models/p24.float.qam /tmp/p24.ptq.qam
//! ```

use anyhow::{Context, Result};
use quantasr::decoder::DecoderConfig;
use quantasr::eval::{build_decoder, evaluate};
use quantasr::io::feat_fmt::read_feats;
use quantasr::io::model_fmt::{QamFile, Tensor};
use quantasr::nn::{AcousticModel, ExecMode};
use quantasr::quant::scheme::QuantParams;
use quantasr::sim::World;

fn main() -> Result<()> {
    let mut args = std::env::args().skip(1);
    let src = args.next().unwrap_or_else(|| "artifacts/models/p24.float.qam".into());
    let dst = args.next().unwrap_or_else(|| "/tmp/quantasr.ptq.qam".into());
    let art = args.next().unwrap_or_else(|| "artifacts".into());

    let mut qam = QamFile::load(&src).context("loading source model")?;
    let before = qam.storage_bytes();
    println!("source: {src} ({} KB)", before / 1024);

    // Quantize every 2-D tensor except the softmax (paper's 'quant' choice).
    let names: Vec<String> = qam.tensors.keys().cloned().collect();
    for name in names {
        let t = qam.tensors.get(&name).unwrap();
        if t.shape().len() != 2 || name.starts_with("out.") {
            continue;
        }
        let w = t.to_f32();
        let p = QuantParams::from_slice(&w);
        let mut data = vec![0u8; w.len()];
        p.quantize_slice(&w, &mut data);
        // report per-tensor error
        let mut rec = vec![0f32; w.len()];
        p.recover_slice(&data, &mut rec);
        let rms = (w
            .iter()
            .zip(&rec)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / w.len() as f64)
            .sqrt();
        println!("  {name:<10} {:?} rms-err {rms:.2e} (½step {:.2e})", t.shape(), p.half_step());
        qam.tensors.insert(
            name,
            Tensor::U8Q {
                shape: t.shape().to_vec(),
                data,
                vmin: p.vmin,
                q: p.q,
            },
        );
    }
    qam.header.quantized = true;
    qam.save(&dst)?;
    let after = qam.storage_bytes();
    println!(
        "quantized: {dst} ({} KB) — {:.2}× smaller",
        after / 1024,
        before as f64 / after as f64
    );

    // WER before vs after (mismatch condition).
    let utts = read_feats(format!("{art}/data/eval_clean.feats"))
        .context("run `make artifacts` first")?;
    let world = World::new();
    let decoder = build_decoder(&world, DecoderConfig::default());
    let m_f = AcousticModel::load(&src, ExecMode::Float)?;
    let m_q = AcousticModel::load(&dst, ExecMode::Quant)?;
    let r_f = evaluate(&m_f, &decoder, &utts, 4);
    let r_q = evaluate(&m_q, &decoder, &utts, 4);
    println!(
        "\nclean eval: float WER {:.2}%  → post-training-quantized WER {:.2}% \
         (relative loss {:+.1}%)",
        100.0 * r_f.wer,
        100.0 * r_q.wer,
        100.0 * (r_q.wer - r_f.wer) / r_f.wer.max(1e-9)
    );
    Ok(())
}
