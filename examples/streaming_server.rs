//! End-to-end serving driver (the DESIGN.md E4 experiment): start the
//! streaming coordinator with a quantized acoustic model, launch N
//! concurrent clients over real TCP, stream synthetic speech in real-time-
//! ish chunks, and report accuracy, latency percentiles, throughput and
//! the AM real-time factor.
//!
//! ```bash
//! cargo run --release --example streaming_server -- \
//!     [--streams 8] [--utts 48] [--mode quant] [--max-batch 32] \
//!     [--deadline-ms 5] [--quantum 25] [--bulk-every 0]
//! ```
//!
//! `--deadline-ms` sets the batch-formation deadline (malformed values
//! warn and keep the default — also settable process-wide via
//! `QUANTASR_BATCH_DEADLINE_MS`); `--quantum` sets the preemption
//! time-slice in ticks; `--bulk-every k` opens every k-th client as a
//! `Bulk`-priority stream (0 = all interactive) to exercise the QoS path.
//!
//! Results are recorded in EXPERIMENTS.md §E4.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};
use quantasr::coordinator::server::{serve, Client};
use quantasr::coordinator::{Engine, EngineConfig};
use quantasr::decoder::DecoderConfig;
use quantasr::eval::build_decoder;
use quantasr::nn::{AcousticModel, ExecMode};
use quantasr::sched::Priority;
use quantasr::sim::dataset::{gen_wave, Style};
use quantasr::sim::World;
use quantasr::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let art = args.get_or("artifacts", "artifacts").to_string();
    let n_streams = args.get_usize("streams", 8);
    let n_utts = args.get_usize("utts", 48);
    let bulk_every = args.get_usize_warn("bulk-every", 0);
    let mode = ExecMode::parse(args.get_or("mode", "quant"))?;

    let world = Arc::new(World::new());
    let model = Arc::new(
        AcousticModel::load(format!("{art}/models/p24.qat.qam"), mode)
            .context("run `make artifacts` first")?,
    );
    let decoder = Arc::new(build_decoder(&world, DecoderConfig::default()));
    let mut cfg = EngineConfig::default();
    cfg.apply_cli_flags(&args);
    let deadline_ms = cfg.policy.deadline.as_secs_f64() * 1e3;
    let max_batch = cfg.policy.max_batch;
    let quantum = cfg.quantum.quantum_ticks;
    let engine = Arc::new(Engine::start(model.clone(), decoder, cfg));
    println!(
        "engine up: model={} mode={mode:?} storage={}KB max_batch={max_batch} \
         deadline={deadline_ms}ms quantum={quantum} ticks",
        model.header.name,
        model.storage_bytes() / 1024,
    );

    // Start the TCP server on an ephemeral port.
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv_engine = engine.clone();
    let srv_stop = stop.clone();
    let server_thread = std::thread::spawn(move || {
        serve(srv_engine, "127.0.0.1:0", srv_stop, move |a| {
            let _ = addr_tx.send(a);
        })
        .expect("server failed");
    });
    let addr = addr_rx.recv()?.to_string();
    println!("server bound on {addr}");

    // N concurrent clients, each streaming utterances in 100 ms chunks.
    let correct = AtomicUsize::new(0);
    let total = AtomicUsize::new(0);
    let total_audio = std::sync::Mutex::new(0.0f64);
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for s in 0..n_streams {
            let addr = addr.clone();
            let world = world.clone();
            let correct = &correct;
            let total = &total;
            let total_audio = &total_audio;
            scope.spawn(move || {
                for u in 0..n_utts.div_ceil(n_streams) {
                    let uid = (s * 4096 + u) as u32;
                    let utt = gen_wave(uid, 0x5E4E, &world, Style::Clean);
                    *total_audio.lock().unwrap() += utt.wave.len() as f64 / 8000.0;
                    let mut client = Client::connect(&addr).expect("connect");
                    if bulk_every > 0 && s % bulk_every == bulk_every - 1 {
                        client.set_priority(Priority::Bulk).expect("set priority");
                    }
                    for chunk in utt.wave.chunks(800) {
                        client.send_audio(chunk).expect("send");
                    }
                    let r = client.finish().expect("finish");
                    total.fetch_add(1, Ordering::Relaxed);
                    if r.words == utt.words {
                        correct.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::SeqCst);
    let _ = server_thread.join();

    let n = total.load(Ordering::Relaxed);
    let audio = *total_audio.lock().unwrap();
    println!("\n=== streaming_server results ===");
    println!(
        "{n} utterances ({audio:.1}s audio) over {n_streams} TCP streams in {wall:.2}s \
         → {:.1} utt/s, {:.2}× realtime aggregate",
        n as f64 / wall,
        audio / wall
    );
    println!(
        "sentence accuracy: {}/{} = {:.1}%",
        correct.load(Ordering::Relaxed),
        n,
        100.0 * correct.load(Ordering::Relaxed) as f64 / n.max(1) as f64
    );
    println!("{}", engine.metrics().report());
    Ok(())
}
