//! Demonstration of the paper's §3 "quantization error and bias" analysis:
//! why the rounding-consistent zero point of eqs. (2)–(3) matters.
//!
//! Shows (a) scalar round-trip error statistics, (b) how bias *accumulates*
//! in long dot products (the LSTM's K≈200 inner dimension), and (c) the
//! variance-preservation claim the paper cites from Gersho & Gray.
//!
//! ```bash
//! cargo run --release --example bias_error
//! ```

use quantasr::quant::error::{dot_bias_experiment, stats_consistent, stats_naive, variance_ratio};
use quantasr::util::rng::Xoshiro256;

fn main() {
    let mut rng = Xoshiro256::new(0xB1A5);

    println!("(a) scalar quantize→recover error, N(0,1) values");
    println!("{:<10} {:>14} {:>12} {:>14} {:>12}", "n", "bias(eq.2/3)", "rms", "bias(naive)", "rms");
    for n in [512usize, 8192, 131072] {
        let mut v = vec![0f32; n];
        rng.fill_normal(&mut v);
        let c = stats_consistent(&v);
        let na = stats_naive(&v);
        println!(
            "{n:<10} {:>14.3e} {:>12.3e} {:>14.3e} {:>12.3e}",
            c.bias, c.rms, na.bias, na.rms
        );
    }

    println!("\n(b) bias accumulation in dot products (|error| vs exact, mean of 500 trials)");
    println!("{:<8} {:>16} {:>14} {:>8}", "k", "consistent", "naive", "ratio");
    for k in [64usize, 256, 1024] {
        let (mut c_sum, mut n_sum) = (0.0, 0.0);
        for _ in 0..500 {
            let mut x = vec![0f32; k];
            let mut w = vec![0f32; k];
            rng.fill_normal(&mut x);
            rng.fill_normal(&mut w);
            let (c, na) = dot_bias_experiment(&x, &w);
            c_sum += c;
            n_sum += na;
        }
        println!(
            "{k:<8} {:>16.4} {:>14.4} {:>7.1}×",
            c_sum / 500.0,
            n_sum / 500.0,
            n_sum / c_sum.max(1e-12)
        );
    }

    println!("\n(c) variance preservation (paper §3, citing Gersho & Gray)");
    let mut v = vec![0f32; 65536];
    rng.fill_normal(&mut v);
    let (vi, vo) = variance_ratio(&v);
    println!("var(V) = {vi:.6}   var(recover(quantize(V))) = {vo:.6}   ratio = {:.5}", vo / vi);
}
