//! Quickstart: synthesize an utterance, run the full embedded pipeline
//! (frontend → quantized LSTM acoustic model → lexicon+LM decoder) and
//! print the transcript next to the truth.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::{Context, Result};
use quantasr::decoder::DecoderConfig;
use quantasr::eval::build_decoder;
use quantasr::frontend;
use quantasr::nn::{AcousticModel, ExecMode};
use quantasr::sim::dataset::{gen_wave, Style};
use quantasr::sim::World;

fn main() -> Result<()> {
    let art = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let world = World::new();

    // The QAT-trained quickstart model, executed with the paper's §3.1
    // integer arithmetic (weights stay in their stored u8 grid).
    let model = AcousticModel::load(format!("{art}/models/p24.qat.qam"), ExecMode::Quant)
        .context("run `make artifacts` first")?;
    println!(
        "model: {} ({} params, {} KB quantized)",
        model.header.name,
        model.header.param_count,
        model.storage_bytes() / 1024
    );
    let decoder = build_decoder(&world, DecoderConfig::default());

    let mut correct = 0;
    let n = 10;
    for uid in 0..n {
        // 1. synthesize speech
        let utt = gen_wave(uid, 0xDE40, &world, Style::Clean);
        // 2. frontend: PCM → 64-d stacked log-mel @ 20ms
        let feats = frontend::features(&utt.wave);
        let frames = feats.len() / frontend::spec::FEAT_DIM;
        // 3. acoustic model: int8 inference
        let log_probs = model.forward_utt(&feats, frames);
        // 4. decode: CTC beam + lexicon trie + LM rescore
        let hyp = decoder.decode(&log_probs, model.num_labels());
        let ok = hyp.words == utt.words;
        correct += ok as usize;
        println!(
            "utt {uid}: {:5.2}s audio, {frames} frames  ref={:?}  hyp={:?}  {}",
            utt.wave.len() as f64 / 8000.0,
            utt.words,
            hyp.words,
            if ok { "✓" } else { "✗" }
        );
    }
    println!("\n{correct}/{n} exact sentence matches");
    Ok(())
}
